//! Deterministic scenario-harness tests (DESIGN.md §9).
//!
//! Everything here runs the **full coordinator** — pool, batcher, merge
//! pipeline, cache — under a virtual clock, so every assertion is about
//! simulated time and scripted faults. No assertion depends on real
//! `Instant` arithmetic or `thread::sleep`; the only wall-clock check is
//! the acceptance bound that the whole virtual replay is fast.
//!
//! Reference engine only: the synthetic scenario environment has no HLO
//! artifacts for the PJRT backend.
#![cfg(not(feature = "pjrt"))]

use loraquant::coordinator::MergeStrategy;
use loraquant::scenario::{
    run_scenario, ChurnAction, ClockMode, EventKind, FaultPlan, ScenarioEnv, ScenarioSpec,
    SlowMerge,
};
use loraquant::workload::WorkloadConfig;
use std::time::{Duration, Instant};

/// The acceptance trace: 4 tenants, Zipf-skewed arrivals, ≥ 200 requests.
fn acceptance_spec(strategy: MergeStrategy) -> ScenarioSpec {
    ScenarioSpec {
        name: format!("acceptance/{strategy}"),
        strategy,
        workload: WorkloadConfig { rate: 400.0, zipf_alpha: 1.1, n_requests: 220, seed: 7 },
        ..Default::default()
    }
}

/// Acceptance: a full 4-tenant Zipf trace replays through all three
/// strategies under virtual time, fast, with byte-identical event logs
/// across two consecutive runs.
#[test]
fn golden_trace_identical_across_runs_and_fast() {
    let env = ScenarioEnv::synth("golden", 4).unwrap();
    let wall0 = Instant::now();
    for strategy in [MergeStrategy::Merged, MergeStrategy::Factor, MergeStrategy::Auto] {
        let spec = acceptance_spec(strategy);
        let a = run_scenario(&spec, &env).unwrap();
        let b = run_scenario(&spec, &env).unwrap();
        assert_eq!(a.summary.requests, 220);
        assert_eq!(a.summary.ok, 220, "{strategy}: every request must complete");
        assert!(!a.log().is_empty());
        assert_eq!(a.log(), b.log(), "{strategy}: golden event log must be reproducible");
        assert_eq!(a.tokens, b.tokens, "{strategy}: token outputs must be reproducible");
        // structural sanity: one submit and one completion per request
        let submits = a.events.iter().filter(|e| matches!(e.kind, EventKind::Submit { .. })).count();
        let completes =
            a.events.iter().filter(|e| matches!(e.kind, EventKind::Complete { .. })).count();
        assert_eq!((submits, completes), (220, 220));
    }
    // ≥ 200 requests × 3 strategies × 2 runs of a multi-hundred-ms trace,
    // replayed in well under 5 s of wall clock.
    assert!(
        wall0.elapsed() < Duration::from_secs(5),
        "virtual replay too slow: {:?}",
        wall0.elapsed()
    );
}

/// Checked-in golden files (ROADMAP scenario-harness follow-up (a)):
/// each acceptance spec's event log must match
/// `tests/golden/acceptance_<strategy>.log` byte for byte — catching
/// drift against history, not just run-vs-run.
///
/// * `LQ_BLESS=1` (re)writes the files; commit the result.
/// * A missing file is reported loudly but does not fail, so a fresh
///   checkout (or a platform whose libm rounds `exp`/`tanh` differently
///   — see tests/golden/README.md) stays green until blessed. CI
///   blesses absent files first and then verifies, and uploads the logs
///   as an artifact.
#[test]
fn golden_trace_files_match_checked_in_logs() {
    let golden_dir =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden");
    let env = ScenarioEnv::synth("goldenfiles", 4).unwrap();
    let bless = std::env::var_os("LQ_BLESS").is_some();
    let mut missing = Vec::new();
    for strategy in [MergeStrategy::Merged, MergeStrategy::Factor, MergeStrategy::Auto] {
        let run = run_scenario(&acceptance_spec(strategy), &env).unwrap();
        assert_eq!(run.summary.ok, 220, "{strategy}: acceptance trace must fully complete");
        let path = golden_dir.join(format!("acceptance_{strategy}.log"));
        if bless {
            std::fs::create_dir_all(&golden_dir).unwrap();
            std::fs::write(&path, run.log()).unwrap();
            eprintln!("blessed {} ({} events)", path.display(), run.events.len());
            continue;
        }
        match std::fs::read_to_string(&path) {
            Ok(want) => assert_eq!(
                run.log(),
                want,
                "{strategy}: trace drifted from the checked-in golden {} — \
                 if the change is intentional, re-bless with LQ_BLESS=1 and commit",
                path.display()
            ),
            Err(_) => missing.push(path),
        }
    }
    for path in &missing {
        eprintln!(
            "golden trace {} not checked in — run `LQ_BLESS=1 cargo test --release \
             --test scenario golden_trace_files` and commit it",
            path.display()
        );
    }
}

/// The compute-threads determinism contract (DESIGN.md §10): prefill
/// threading is a wall-clock knob only. Under the virtual clock decode
/// takes zero simulated time and thread count never changes logits, so
/// the whole event log — not just the tokens — is byte-identical at any
/// `compute_threads`.
#[test]
fn compute_threads_do_not_change_golden_traces() {
    let env = ScenarioEnv::synth("cthreads", 4).unwrap();
    for strategy in [MergeStrategy::Merged, MergeStrategy::Factor] {
        let serial = run_scenario(&acceptance_spec(strategy), &env).unwrap();
        let threaded = ScenarioSpec { compute_threads: 4, ..acceptance_spec(strategy) };
        let b = run_scenario(&threaded, &env).unwrap();
        assert_eq!(serial.log(), b.log(), "{strategy}: trace must not depend on threads");
        assert_eq!(serial.tokens, b.tokens, "{strategy}: tokens must not depend on threads");
    }
}

/// Chunked prefill (DESIGN.md §13) through the full coordinator: with
/// `prefill_chunk` > 0 every prompt longer than the chunk streams into
/// its decode group incrementally, yet token outputs stay identical to
/// monolithic admission and the chunked trace is itself deterministic —
/// across runs and across compute-thread counts.
#[test]
fn chunked_prefill_keeps_tokens_identical_and_traces_deterministic() {
    let env = ScenarioEnv::synth("chunkspec", 4).unwrap();
    for strategy in [MergeStrategy::Merged, MergeStrategy::Factor] {
        let mono = run_scenario(&acceptance_spec(strategy), &env).unwrap();
        // chunk 2 forces the chunked path on every multi-token prompt
        let chunked = |threads| ScenarioSpec {
            prefill_chunk: 2,
            compute_threads: threads,
            ..acceptance_spec(strategy)
        };
        let a = run_scenario(&chunked(1), &env).unwrap();
        assert_eq!(a.summary.ok, 220, "{strategy}: chunked trace must fully complete");
        assert_eq!(a.tokens, mono.tokens, "{strategy}: chunking must not change tokens");
        let b = run_scenario(&chunked(4), &env).unwrap();
        assert_eq!(a.log(), b.log(), "{strategy}: chunked trace must not depend on threads");
        assert_eq!(a.tokens, b.tokens, "{strategy}: chunked tokens must not depend on threads");
    }
}

/// Determinism of *results*, not schedule: per-request token output is
/// identical across pool sizes (routing and batch composition change,
/// but the reference forward is per-lane independent).
#[test]
fn token_outputs_identical_across_worker_counts() {
    let env = ScenarioEnv::synth("workers", 4).unwrap();
    for strategy in [MergeStrategy::Merged, MergeStrategy::Factor] {
        let one = run_scenario(&acceptance_spec(strategy).with_workers(1), &env).unwrap();
        let four = run_scenario(&acceptance_spec(strategy).with_workers(4), &env).unwrap();
        assert_eq!(one.summary.ok, 220);
        assert_eq!(four.summary.ok, 220);
        assert_eq!(
            one.tokens, four.tokens,
            "{strategy}: per-request tokens must not depend on pool size"
        );
    }
}

/// With no faults, virtual end-to-end latency is pure scheduling delay:
/// decode and (ungated) merges take zero virtual time, so no request can
/// ever wait longer than the batcher's max-wait deadline.
#[test]
fn unfaulted_latency_is_bounded_by_max_wait() {
    let env = ScenarioEnv::synth("latbound", 4).unwrap();
    for strategy in [MergeStrategy::Merged, MergeStrategy::Factor, MergeStrategy::Auto] {
        for workers in [1usize, 3] {
            let spec = acceptance_spec(strategy).with_workers(workers);
            let run = run_scenario(&spec, &env).unwrap();
            assert_eq!(run.summary.ok, run.summary.requests);
            assert!(
                run.summary.latency.max() <= spec.max_wait,
                "{strategy}/w{workers}: max e2e {:?} exceeds max_wait {:?}",
                run.summary.latency.max(),
                spec.max_wait
            );
        }
    }
}

/// Fault injection: under a scripted 50 ms slow merge, `merged` parks the
/// cold batches for the full delay while `auto` serves them factor-form
/// with **zero added virtual latency**.
#[test]
fn slow_merge_parks_merged_but_not_auto() {
    let env = ScenarioEnv::synth("slowmerge", 2).unwrap();
    let delay = Duration::from_millis(50);
    let spec = |strategy| ScenarioSpec {
        name: format!("slow/{strategy}"),
        strategy,
        n_adapters: 1,
        round_robin: true,
        // bucket 4 = the request count: the batch releases on bucket-full
        // at the 4th (near-instant) arrival, not at the max-wait deadline
        buckets: vec![1, 4],
        workload: WorkloadConfig { rate: 1e9, zipf_alpha: 0.0, n_requests: 4, seed: 3 },
        faults: FaultPlan {
            slow_merge: Some(SlowMerge { adapter: None, delay }),
            ..Default::default()
        },
        ..Default::default()
    };

    let merged = run_scenario(&spec(MergeStrategy::Merged), &env).unwrap();
    assert_eq!(merged.summary.ok, 4);
    assert!(
        merged.summary.latency.quantile(0.0) >= delay,
        "merged: cold batch must park for the scripted merge ({:?})",
        merged.summary.latency.quantile(0.0)
    );
    assert_eq!(merged.summary.merges.started, 1, "one merge for the one adapter");

    let auto = run_scenario(&spec(MergeStrategy::Auto), &env).unwrap();
    assert_eq!(auto.summary.ok, 4);
    assert!(
        auto.summary.latency.max() < Duration::from_millis(1),
        "auto: cold requests must be served factor-form instantly, got {:?}",
        auto.summary.latency.max()
    );
    assert!(auto.summary.factor_batches >= 1, "cold batch decoded factor-form");
    assert_eq!(auto.summary.merges.started, 1, "background merge still warmed the cache");
    // the background merge began while requests were already being
    // answered: its MergeBegin is in the log at the batch-release instant
    assert!(auto.events.iter().any(|e| matches!(e.kind, EventKind::MergeBegin { .. })));
    // both fault runs are themselves golden
    let merged2 = run_scenario(&spec(MergeStrategy::Merged), &env).unwrap();
    assert_eq!(merged.log(), merged2.log(), "fault-injected trace must be reproducible");
}

/// Cache-budget thrash + registry churn: with a budget that holds ~one
/// merged adapter, eight tenants evict each other constantly and fresh
/// tenants register mid-trace — yet no request ever fails: an adapter is
/// never evicted mid-decode, and every miss re-merges.
#[test]
fn cache_thrash_with_churn_never_breaks_decode() {
    let env = ScenarioEnv::synth("thrash", 8).unwrap();
    let spec = ScenarioSpec {
        name: "thrash".into(),
        strategy: MergeStrategy::Merged,
        n_adapters: 8,
        // ~one synthetic merged weight set (≈ 50 KB): constant eviction
        cache_budget_bytes: 64 << 10,
        workload: WorkloadConfig { rate: 400.0, zipf_alpha: 0.3, n_requests: 200, seed: 29 },
        faults: FaultPlan {
            churn: vec![
                ChurnAction::Register { at: Duration::from_millis(100), pool_index: 1 },
                ChurnAction::Register { at: Duration::from_millis(250), pool_index: 2 },
            ],
            ..Default::default()
        },
        ..Default::default()
    };
    let a = run_scenario(&spec, &env).unwrap();
    assert_eq!(a.summary.ok, 200, "thrash must never fail a request: {} failed", a.summary.failed);
    assert!(a.summary.cache.evictions > 0, "budget was supposed to thrash");
    assert!(a.summary.merges.started as usize > 8, "evicted adapters must re-merge on return");
    let registers =
        a.events.iter().filter(|e| matches!(e.kind, EventKind::Register { .. })).count();
    assert_eq!(registers, 10, "8 initial + 2 churned-in");
    // thrash + churn is still golden (merge_workers = 1 pins LRU order)
    let b = run_scenario(&spec, &env).unwrap();
    assert_eq!(a.log(), b.log(), "thrash trace must be reproducible");
}

/// Removing a tenant mid-trace fails its remaining arrivals fast, is
/// visible in the event log, and leaves every other tenant unharmed.
#[test]
fn mid_trace_remove_fails_fast_and_spares_other_tenants() {
    let env = ScenarioEnv::synth("remove", 4).unwrap();
    let spec = ScenarioSpec {
        name: "remove".into(),
        n_adapters: 4,
        round_robin: true, // every tenant keeps arriving all trace long
        workload: WorkloadConfig { rate: 200.0, zipf_alpha: 0.0, n_requests: 120, seed: 13 },
        faults: FaultPlan {
            churn: vec![ChurnAction::Remove { at: Duration::from_millis(150), target: 0 }],
            ..Default::default()
        },
        ..Default::default()
    };
    let run = run_scenario(&spec, &env).unwrap();
    assert_eq!(run.summary.ok + run.summary.failed, 120, "every request accounted for");
    assert!(run.summary.failed > 0, "the removed tenant's arrivals must fail");
    assert!(run.events.iter().any(|e| matches!(e.kind, EventKind::Remove { adapter: 0 })));
    // all failures name the removed adapter (rejected at submit, or
    // already queued/merging when the registry entry vanished)
    for e in &run.events {
        if let EventKind::Fail { adapter, error, .. } = &e.kind {
            assert_eq!(*adapter, 0, "only the removed tenant may fail");
            assert!(error.contains("adapter 0"), "unexpected failure: {error}");
        }
    }
    let per_tenant_ok: Vec<usize> = (0..4)
        .map(|id| {
            run.events
                .iter()
                .filter(|e| matches!(e.kind, EventKind::Complete { adapter, .. } if adapter == id))
                .count()
        })
        .collect();
    assert_eq!(per_tenant_ok[1], 30, "tenant 1 sees all 30 of its arrivals");
    assert_eq!(per_tenant_ok[2], 30);
    assert_eq!(per_tenant_ok[3], 30);
    // reproducible including the scripted outage
    let again = run_scenario(&spec, &env).unwrap();
    assert_eq!(run.log(), again.log());
}

/// Prefetch under virtual time: warmed adapters never miss on the
/// request path, and the acks appear in the event log.
#[test]
fn virtual_prefetch_eliminates_request_path_misses() {
    let env = ScenarioEnv::synth("vprefetch", 4).unwrap();
    let spec = ScenarioSpec {
        name: "vprefetch".into(),
        n_adapters: 4,
        prefetch: true,
        workload: WorkloadConfig { rate: 400.0, zipf_alpha: 1.1, n_requests: 64, seed: 17 },
        ..Default::default()
    };
    let run = run_scenario(&spec, &env).unwrap();
    assert_eq!(run.summary.ok, 64);
    assert_eq!(run.summary.cache.misses, 0, "prefetched adapters must not miss");
    let acks = run
        .events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::Prefetch { ok: true, .. }))
        .count();
    assert_eq!(acks, 4);
}

/// The continuous-batching acceptance (DESIGN.md §11): on a
/// staggered-arrival, mixed-length trace whose batches pile up behind a
/// scripted slow merge, the post-merge drain feeds every parked batch
/// into one scheduler session — freed lanes are reused mid-flight — so
/// the continuous run spends **strictly fewer virtual decode steps**
/// than the lock-step run while producing token-identical outputs.
#[test]
fn continuous_batching_reduces_decode_steps_on_staggered_mixed_lengths() {
    let env = ScenarioEnv::synth("contsteps", 1).unwrap();
    let spec = |continuous: bool| ScenarioSpec {
        name: format!("contsteps/{}", if continuous { "continuous" } else { "lockstep" }),
        strategy: MergeStrategy::Merged,
        continuous,
        n_adapters: 1,
        // 12 staggered arrivals land while the adapter's merge is parked
        // for 50 ms: a full bucket of 8 plus a deadline-released 4 park
        // behind it and drain together at the merge wake
        buckets: vec![1, 8],
        workload: WorkloadConfig { rate: 4000.0, zipf_alpha: 0.0, n_requests: 12, seed: 41 },
        // mixed budgets 1..=8: short lanes free mid-flight
        max_new_spread: 8,
        faults: FaultPlan {
            slow_merge: Some(SlowMerge { adapter: None, delay: Duration::from_millis(50) }),
            ..Default::default()
        },
        ..Default::default()
    };
    let cont = run_scenario(&spec(true), &env).unwrap();
    let lock = run_scenario(&spec(false), &env).unwrap();
    assert_eq!(cont.summary.ok, 12);
    assert_eq!(lock.summary.ok, 12);
    assert_eq!(
        cont.tokens, lock.tokens,
        "continuous batching must not change a single token"
    );
    assert!(cont.summary.decode_steps > 0);
    assert!(
        cont.summary.decode_steps < lock.summary.decode_steps,
        "freed lanes must be reused: continuous {} steps vs lock-step {}",
        cont.summary.decode_steps,
        lock.summary.decode_steps
    );
    // the parked batches drained as one group instead of one per batch
    assert!(cont.summary.batches < lock.summary.batches);
    // both runs are themselves golden
    let cont2 = run_scenario(&spec(true), &env).unwrap();
    assert_eq!(cont.log(), cont2.log(), "continuous trace must be reproducible");
    let lock2 = run_scenario(&spec(false), &env).unwrap();
    assert_eq!(lock.log(), lock2.log(), "lock-step trace must be reproducible");
}

/// Run-to-run byte identity across the full determinism matrix
/// (acceptance): compute_threads ∈ {1, 4} × merge_workers ∈ {1, 2} on a
/// merge-heavy thrash trace. `merge_workers: 2` is the case the ingest
/// sequencer exists for — merge completions race on two threads, but
/// each worker applies them in submission order, so LRU eviction (and
/// therefore every later hit/miss/merge) replays identically.
#[test]
fn golden_traces_hold_across_compute_threads_and_merge_workers() {
    let env = ScenarioEnv::synth("detmatrix", 6).unwrap();
    for (compute_threads, merge_workers) in [(1usize, 1usize), (4, 1), (1, 2), (4, 2)] {
        let spec = ScenarioSpec {
            name: format!("detmatrix/t{compute_threads}/m{merge_workers}"),
            strategy: MergeStrategy::Merged,
            compute_threads,
            merge_workers,
            n_adapters: 6,
            // ~one merged set: constant eviction → constant re-merges →
            // maximal sensitivity to merge-ingest order
            cache_budget_bytes: 64 << 10,
            workload: WorkloadConfig { rate: 400.0, zipf_alpha: 0.3, n_requests: 120, seed: 59 },
            ..Default::default()
        };
        let a = run_scenario(&spec, &env).unwrap();
        let b = run_scenario(&spec, &env).unwrap();
        assert_eq!(a.summary.ok, 120, "t{compute_threads}/m{merge_workers}");
        assert!(
            a.summary.merges.started > 6,
            "t{compute_threads}/m{merge_workers}: trace must exercise re-merges"
        );
        assert_eq!(
            a.log(),
            b.log(),
            "t{compute_threads}/m{merge_workers}: event log must be byte-identical run-to-run"
        );
        assert_eq!(a.tokens, b.tokens, "t{compute_threads}/m{merge_workers}");
    }
}

/// The real-time mode drives the same spec type through the same code
/// path (the bench entry point) — smoke-check it end to end.
#[test]
fn real_time_mode_smoke() {
    let env = ScenarioEnv::synth("realtime", 4).unwrap();
    let spec = ScenarioSpec {
        name: "realtime".into(),
        mode: ClockMode::RealTime,
        n_adapters: 4,
        workload: WorkloadConfig { rate: 1e9, zipf_alpha: 0.0, n_requests: 16, seed: 19 },
        ..Default::default()
    };
    let run = run_scenario(&spec, &env).unwrap();
    assert_eq!(run.summary.ok, 16);
    assert!(run.summary.trace_span <= run.summary.makespan);
    assert!(run.tokens.iter().all(Option::is_some));
}
