//! Observability acceptance (DESIGN.md §16): exported request-lifecycle
//! traces are byte-identical across runs, compute-thread counts, and
//! worker counts — including faulted traces — stage accounting
//! telescopes exactly (`Σ stages == e2e`), failures name the stage the
//! fault struck in, and the Prometheus exposition renders
//! deterministically. Recording must also be an observer: disabling it
//! must not perturb the schedule by a single byte.
//!
//! Reference engine only: the synthetic scenario environment has no HLO
//! artifacts for the PJRT backend.
#![cfg(not(feature = "pjrt"))]

use loraquant::coordinator::MergeStrategy;
use loraquant::obs::{SpanKind, Stage};
use loraquant::scenario::{
    run_scenario, ChurnAction, EventKind, FaultPlan, ScenarioEnv, ScenarioRun, ScenarioSpec,
};
use loraquant::workload::WorkloadConfig;
use std::time::Duration;

const MS: fn(u64) -> Duration = Duration::from_millis;

/// A deadline storm (2000/s against a 15 ms deadline): plenty of OK
/// traffic, plenty of structured timeout failures — the faulted trace
/// the byte-identity and stage-accounting assertions run against.
fn storm_spec(threads: usize, workers: usize) -> ScenarioSpec {
    ScenarioSpec {
        name: "obs/storm".into(),
        strategy: MergeStrategy::Merged,
        compute_threads: threads,
        workers,
        max_wait: Duration::from_secs(1),
        request_timeout: Some(MS(15)),
        workload: WorkloadConfig { rate: 2000.0, zipf_alpha: 1.1, n_requests: 200, seed: 7 },
        ..Default::default()
    }
}

/// Cache-budget thrash + a scripted availability flap: constant
/// eviction/re-merge churn on the merge pool plus fail-fast quarantine
/// failures, replayed at several merge-worker counts.
fn thrash_spec(threads: usize, merge_workers: usize) -> ScenarioSpec {
    ScenarioSpec {
        name: "obs/thrash".into(),
        strategy: MergeStrategy::Merged,
        compute_threads: threads,
        merge_workers,
        n_adapters: 8,
        // ~one synthetic merged weight set: constant eviction
        cache_budget_bytes: 64 << 10,
        workload: WorkloadConfig { rate: 400.0, zipf_alpha: 0.3, n_requests: 200, seed: 29 },
        faults: FaultPlan {
            churn: vec![
                ChurnAction::Quarantine { at: MS(150), target: 3 },
                ChurnAction::Recover { at: MS(300), target: 3 },
            ],
            ..Default::default()
        },
        ..Default::default()
    }
}

/// The exported trace and the event log of two runs must match byte for
/// byte.
fn assert_same_trace(a: &ScenarioRun, b: &ScenarioRun, what: &str) {
    assert_eq!(a.log(), b.log(), "{what}: event log must be byte-identical");
    assert_eq!(a.trace_json(), b.trace_json(), "{what}: trace export must be byte-identical");
}

/// Byte-identical faulted traces across runs, compute threads, and
/// worker counts: span timestamps come from the frozen virtual clock
/// and span identity is logical (request tag, adapter id), so nothing
/// in the export can depend on thread interleaving or routing.
#[test]
fn faulted_trace_is_byte_identical_across_runs_threads_and_workers() {
    let env = ScenarioEnv::synth("obs_storm", 4).unwrap();
    let run = run_scenario(&storm_spec(1, 1), &env).unwrap();
    assert!(run.summary.ok > 0 && run.summary.failed > 0, "the storm must fault the trace");
    assert!(!run.spans.is_empty(), "tracing is on by default");
    let again = run_scenario(&storm_spec(1, 1), &env).unwrap();
    assert_same_trace(&run, &again, "rerun");
    let threaded = run_scenario(&storm_spec(4, 1), &env).unwrap();
    assert_same_trace(&run, &threaded, "compute-threads 4");
    let pooled = run_scenario(&storm_spec(1, 4), &env).unwrap();
    assert_same_trace(&run, &pooled, "workers 4");
}

/// The thrash trace exercises the merge-pool job spans hard (constant
/// eviction → constant re-merge) and still exports byte-identically
/// across runs, compute threads, and merge-worker counts. (Worker-pool
/// counts are exercised on the storm spec above: per-worker caches make
/// a *thrash* schedule worker-dependent by design — the event log
/// differs too.)
#[test]
fn thrash_trace_is_byte_identical_across_merge_worker_counts() {
    let env = ScenarioEnv::synth("obs_thrash", 8).unwrap();
    let run = run_scenario(&thrash_spec(1, 1), &env).unwrap();
    assert!(run.summary.failed > 0, "the quarantine window must fail some arrivals");
    assert!(run.summary.cache.evictions > 0, "budget was supposed to thrash");
    let merge_jobs = run
        .spans
        .iter()
        .filter(|s| matches!(s.kind, SpanKind::MergeJob { .. }))
        .count();
    assert!(merge_jobs > 8, "evicted adapters must re-merge, each visible as a job span");
    let again = run_scenario(&thrash_spec(1, 1), &env).unwrap();
    assert_same_trace(&run, &again, "rerun");
    let threaded = run_scenario(&thrash_spec(4, 1), &env).unwrap();
    assert_same_trace(&run, &threaded, "compute-threads 4");
    let pooled = run_scenario(&thrash_spec(1, 4), &env).unwrap();
    assert_same_trace(&run, &pooled, "merge-workers 4");
}

/// `queued + merge_wait + fetch_wait + prefill + decode == e2e`, exactly,
/// for every completed request — and a failed request's breakdown spans
/// exactly submit → failure, with `terminal` naming the stage the
/// timeout struck in.
#[test]
fn stage_accounting_telescopes_exactly() {
    let env = ScenarioEnv::synth("obs_stages", 4).unwrap();
    let run = run_scenario(&storm_spec(1, 1), &env).unwrap();
    let mut checked_ok = 0;
    for e in &run.events {
        match &e.kind {
            EventKind::Complete { req, e2e, .. } => {
                let b = run.stages[*req].expect("completed request must carry a breakdown");
                assert_eq!(b.sum(), *e2e, "req {req}: Σ stages must equal e2e exactly");
                assert_eq!(
                    b.terminal,
                    Stage::Decode,
                    "req {req}: a completed request retires decoding"
                );
                checked_ok += 1;
            }
            EventKind::Fail { req, .. } => {
                let b = run.stages[*req].expect("a timed-out request must carry a breakdown");
                // a timeout retires at exactly submit + deadline, so the
                // telescoped breakdown spans exactly the deadline
                assert_eq!(b.sum(), MS(15), "req {req}: breakdown must span submit → failure");
                if b.terminal == Stage::Queued {
                    assert_eq!(b.queued, MS(15), "req {req}: a queued expiry waited it all out");
                }
            }
            _ => {}
        }
    }
    assert_eq!(checked_ok, run.summary.ok, "every completion was checked");
    // the summary reports per-stage percentiles for all five stages,
    // pool-wide and per adapter
    assert_eq!(run.summary.stage_latency.len(), 5);
    assert!(!run.summary.per_adapter_stages.is_empty());
    let decode = run
        .summary
        .stage_latency
        .iter()
        .find(|(s, _)| *s == Stage::Decode)
        .map(|(_, l)| l.quantile(0.5))
        .unwrap();
    assert!(decode > Duration::ZERO, "completed requests spent time decoding");
    // every retirement is visible in the span trace as a terminal marker
    let retired =
        run.spans.iter().filter(|s| matches!(s.kind, SpanKind::Retired { .. })).count();
    let failed = run
        .spans
        .iter()
        .filter(|s| matches!(&s.kind, SpanKind::Failed { kind, .. } if kind == "timeout"))
        .count();
    assert_eq!(retired, run.summary.ok, "one Retired marker per completion");
    assert_eq!(failed, run.summary.failed, "one Failed:timeout marker per expiry");
}

/// The Prometheus exposition renders deterministically (BTreeMap line
/// order), reflects the scenario's counters, and includes full bucket
/// exports for the latency histograms.
#[test]
fn prometheus_exposition_is_deterministic_and_complete() {
    let env = ScenarioEnv::synth("obs_prom", 4).unwrap();
    let run = run_scenario(&storm_spec(1, 1), &env).unwrap();
    let text = &run.metrics_text;
    assert!(text.starts_with("# HELP"), "exposition must lead with metadata: {text}");
    for needle in [
        "# TYPE lq_requests_total counter",
        "# TYPE lq_e2e_latency_us histogram",
        "lq_e2e_latency_us_bucket{le=\"+Inf\"}",
        "lq_queue_depth{worker=\"0\"}",
        "lq_cache_bytes{worker=\"0\"}",
        "lq_quarantined_adapters 0",
        "lq_trace_dropped_spans_total 0",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    let timeouts = format!("lq_timeouts_total {}\n", run.summary.timeouts);
    assert!(text.contains(&timeouts), "missing {timeouts:?} in:\n{text}");
    let again = run_scenario(&storm_spec(1, 1), &env).unwrap();
    assert_eq!(*text, again.metrics_text, "exposition must be byte-identical across runs");
    let threaded = run_scenario(&storm_spec(4, 1), &env).unwrap();
    assert_eq!(*text, threaded.metrics_text, "exposition must not depend on compute threads");
}

/// Tracing is an observer: turning it off must not change the schedule
/// (byte-identical event log, identical stage accounting) — it only
/// empties the span export.
#[test]
fn disabling_tracing_does_not_perturb_the_schedule() {
    let env = ScenarioEnv::synth("obs_off", 4).unwrap();
    let on = run_scenario(&storm_spec(1, 1), &env).unwrap();
    let off = run_scenario(&ScenarioSpec { trace: false, ..storm_spec(1, 1) }, &env).unwrap();
    assert_eq!(on.log(), off.log(), "recording must not perturb the schedule");
    assert_eq!(on.tokens, off.tokens, "nor any token");
    assert_eq!(on.stages, off.stages, "stage accounting is always on; only spans are gated");
    assert!(off.spans.is_empty(), "no recorder, no spans");
    assert_eq!(off.trace_json(), "{\"traceEvents\":[\n]}\n");
    assert!(!off.metrics_text.contains("lq_trace_dropped_spans_total"));
}
