//! Runtime end-to-end tests: HLO artifacts → PJRT → numerics (skipped with
//! a notice when artifacts are missing).
//!
//! Includes the cross-layer check that the AOT-lowered **Pallas** fused
//! quantized sub-LoRA apply (artifacts/lora_apply.hlo.txt) matches the
//! rust-side dequantized computation bit-for-bit-ish.

use loraquant::eval::{evaluate, EvalSet};
use loraquant::model::{merge_adapter, BaseWeights};
use loraquant::runtime::Engine;
use std::path::Path;

const MODEL: &str = "tiny-llama-s";

fn have_model_artifacts() -> bool {
    Path::new("artifacts").join(MODEL).join("base.bin").exists()
        && Path::new("artifacts").join(format!("{MODEL}.fwd.b8.hlo.txt")).exists()
}

#[test]
fn fwd_artifact_runs_and_is_deterministic() {
    if !have_model_artifacts() {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let base = BaseWeights::load(Path::new("artifacts").join(MODEL)).unwrap();
    let mut engine = Engine::new("artifacts").unwrap();
    engine.load_model_fwd(MODEL, 8, base.cfg.param_names().len()).unwrap();
    let deltas = std::collections::BTreeMap::new();
    let merged = merge_adapter(&base, &deltas).unwrap();
    let weights = engine.upload_weights(&merged).unwrap();
    let tokens = vec![1i32; 8 * base.cfg.seq_len];
    let l1 = engine.forward(&format!("{MODEL}/b8"), &tokens, &[8, base.cfg.seq_len], &weights).unwrap();
    let l2 = engine.forward(&format!("{MODEL}/b8"), &tokens, &[8, base.cfg.seq_len], &weights).unwrap();
    assert_eq!(l1.len(), 8 * base.cfg.seq_len * base.cfg.vocab);
    assert_eq!(l1, l2, "same inputs must give identical logits");
    assert!(l1.iter().all(|v| v.is_finite()));
}

#[test]
fn eval_harness_scores_fp16_adapter_better_than_base() {
    if !have_model_artifacts() {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let dir = Path::new("artifacts").join(MODEL);
    let base = BaseWeights::load(&dir).unwrap();
    let mut engine = Engine::new("artifacts").unwrap();
    engine.load_model_fwd(MODEL, 8, base.cfg.param_names().len()).unwrap();
    let set = EvalSet::load(dir.join("transform.eval.bin")).unwrap().truncated(48);

    let empty = std::collections::BTreeMap::new();
    let base_w = engine.upload_weights(&merge_adapter(&base, &empty).unwrap()).unwrap();
    let base_score = evaluate(&engine, MODEL, 8, &base.cfg, &base_w, &set).unwrap().score;

    let lora = loraquant::adapter::LoraAdapter::load(dir.join("transform.lora.bin")).unwrap();
    let deltas = loraquant::model::merge::fp_deltas(&lora);
    let lw = engine.upload_weights(&merge_adapter(&base, &deltas).unwrap()).unwrap();
    let lora_score = evaluate(&engine, MODEL, 8, &base.cfg, &lw, &set).unwrap().score;

    assert!(
        lora_score > base_score + 20.0,
        "LoRA must carry the skill: base {base_score} vs lora {lora_score}"
    );
}

/// Cross-layer contract: the Pallas kernel artifact (L1, lowered through
/// L2's AOT path) computes the same fused quantized sub-LoRA apply as the
/// rust quantizers (L3). Raw-HLO execution exists only on the PJRT
/// backend, so this test is compiled out of the reference-engine build.
#[cfg(feature = "pjrt")]
#[test]
fn pallas_kernel_artifact_matches_rust_dequant() {
    use loraquant::adapter::fmt::Tensor;
    use loraquant::quant::{bin_dequant, bin_quant, rtn_dequant, rtn_quant};
    use loraquant::tensor::{matmul, matmul_a_bt, Matrix};
    use loraquant::testutil::Rng;

    let path = Path::new("artifacts/lora_apply.hlo.txt");
    if !path.exists() {
        eprintln!("skipping: lora_apply artifact missing");
        return;
    }
    // Shapes fixed by python/compile/aot.py KERNEL_SHAPE.
    let (bsz, n, m, h, rl, group) = (8usize, 128usize, 128usize, 4usize, 12usize, 64usize);
    let mut rng = Rng::new(909);
    let x = rng.matrix(bsz, n, 1.0);
    let ah = rng.matrix(h, n, 1.0);
    let bh_t = rng.matrix(h, m, 1.0);
    let al = rng.matrix(rl, n, 1.0);
    let bl_t = rng.matrix(rl, m, 1.0);

    // quantize with the rust primitives (same conventions as the kernel)
    let qah = rtn_quant(&ah, 2, group);
    let qbh = rtn_quant(&bh_t, 2, group);
    let qal = bin_quant(&al, group);
    let qbl = bin_quant(&bl_t, group);

    // rust-side reference: y = x AhᵀBh + x AlᵀBl on dequantized factors
    let y_ref = {
        let ahd = rtn_dequant(&qah);
        let bhd = rtn_dequant(&qbh);
        let ald = bin_dequant(&qal);
        let bld = bin_dequant(&qbl);
        let yh = matmul(&matmul_a_bt(&x, &ahd), &bhd);
        let yl = matmul(&matmul_a_bt(&x, &ald), &bld);
        yh.add(&yl)
    };

    // run the AOT-lowered Pallas kernel through PJRT
    let mut engine = Engine::new("artifacts").unwrap();
    engine.load_program("lora_apply", "lora_apply.hlo.txt", 11).unwrap();
    let gpr = n / group;
    let inputs = vec![
        Tensor::f32(vec![bsz, n], x.data().to_vec()),
        Tensor::u8(vec![h, n / 4], qah.packed.clone()),
        Tensor::f32(vec![h, gpr], qah.scale.clone()),
        Tensor::f32(vec![h, gpr], qah.zero.clone()),
        Tensor::u8(vec![h, m / 4], qbh.packed.clone()),
        Tensor::f32(vec![h, m / group], qbh.scale.clone()),
        Tensor::f32(vec![h, m / group], qbh.zero.clone()),
        Tensor::u8(vec![rl, n / 8], qal.packed.clone()),
        Tensor::f32(vec![rl, gpr], qal.scale.clone()),
        Tensor::u8(vec![rl, m / 8], qbl.packed.clone()),
        Tensor::f32(vec![rl, m / group], qbl.scale.clone()),
    ];
    // first input is "tokens" in Engine::execute's API; reuse upload path:
    let dev = engine.upload_weights(&inputs[1..].to_vec()).unwrap();
    let xbuf = engine
        .client()
        .buffer_from_host_buffer::<f32>(x.data(), &[bsz, n], None)
        .unwrap();
    let y = engine.execute("lora_apply", &xbuf, &dev).unwrap();
    assert_eq!(y.len(), bsz * m);
    let y_mat = Matrix::from_vec(bsz, m, y);
    let err = y_mat.rel_err(&y_ref);
    assert!(err < 1e-4, "pallas artifact vs rust dequant: rel err {err}");
}
