//! Property-based tests over the crate's core invariants, via the
//! in-tree mini-framework (testutil::check — proptest is unavailable
//! offline). Each property runs 64 random cases; failures report the
//! replaying seed.

use loraquant::loraquant::{
    quantize_site, reparameterize, select_h, split_at, HSelect, LoraQuantConfig, LowMode,
    QuantizedLora,
};
use loraquant::quant::{
    bin_dequant, bin_quant, pack_codes, rtn_dequant, rtn_quant, unpack_codes, Axis, QuantAxis,
};
use loraquant::tensor::{matmul, matmul_a_bt, Matrix};
use loraquant::testutil::{check, check_with, Config, Rng};

fn rand_dims(rng: &mut Rng) -> (usize, usize, usize) {
    let m = [32, 64, 96, 128][rng.below(4)];
    let n = [32, 64, 128][rng.below(3)];
    let r = [4, 8, 16][rng.below(3)];
    (m, n, r)
}

#[test]
fn prop_svd_split_is_exact_for_any_h() {
    check("split_at(h) sums to BA", |rng| {
        let (m, n, r) = rand_dims(rng);
        let decay = rng.range_f32(0.4, 0.95);
        let (b, a) = rng.lora_pair(m, n, r, decay);
        let ba = matmul(&b, &a);
        let rp = reparameterize(&b, &a);
        let h = rng.below(r + 1);
        let sub = split_at(&rp, h);
        let err = sub.reconstruct().rel_err(&ba);
        assert!(err < 2e-3, "h={h} err={err}");
    });
}

#[test]
fn prop_variance_rule_definition() {
    check("select_h(Ratio) is the smallest h covering rho", |rng| {
        let r = rng.range(2, 24);
        let mut s: Vec<f32> = (0..r).map(|_| rng.f32() + 1e-3).collect();
        s.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let rho = rng.range_f32(0.05, 1.0);
        let h = select_h(&s, HSelect::Ratio(rho));
        let total: f64 = s.iter().map(|x| (*x as f64).powi(2)).sum();
        let cover = |k: usize| s[..k].iter().map(|x| (*x as f64).powi(2)).sum::<f64>() / total;
        assert!(h >= 1 && h <= s.len());
        assert!(cover(h) >= rho as f64 - 1e-6, "h={h} covers {}", cover(h));
        if h > 1 {
            assert!(cover(h - 1) < rho as f64 + 1e-6, "h-1 already covers");
        }
    });
}

#[test]
fn prop_rtn_roundtrip_error_bounded_by_scale() {
    check("rtn dequant error <= scale", |rng| {
        let rows = rng.range(1, 8);
        let cols = [32, 64, 100][rng.below(3)];
        let std = rng.range_f32(0.1, 3.0);
        let w = rng.matrix(rows, cols, std);
        let bits = 1 + rng.below(4) as u32;
        let group = [16, 32, 64][rng.below(3)];
        let q = rtn_quant(&w, bits, group);
        let wd = rtn_dequant(&q);
        let gpr = q.groups_per_row();
        for i in 0..rows {
            for j in 0..cols {
                let s = q.scale[i * gpr + j / group].abs();
                let e = (w.at(i, j) - wd.at(i, j)).abs();
                assert!(e <= s * 1.01 + 1e-6, "bits={bits} e={e} s={s}");
            }
        }
    });
}

#[test]
fn prop_bin_scale_is_group_l1_mean_and_sign_preserved() {
    check("binarization: sign kept, |dequant| = group L1 mean", |rng| {
        let rows = rng.range(1, 6);
        let std = rng.range_f32(0.2, 2.0);
        let w = rng.matrix(rows, 64, std);
        let q = bin_quant(&w, 32);
        let wd = bin_dequant(&q);
        for i in 0..w.rows() {
            for j in 0..64 {
                assert_eq!(w.at(i, j) >= 0.0, wd.at(i, j) >= 0.0);
                let s = q.scale[i * 2 + j / 32];
                assert!((wd.at(i, j).abs() - s).abs() < 1e-6);
            }
        }
    });
}

#[test]
fn prop_packing_roundtrips_all_widths() {
    check("pack/unpack identity", |rng| {
        let bits = 1 + rng.below(8) as u32;
        let len = rng.below(200);
        let codes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
        assert_eq!(unpack_codes(&pack_codes(&codes, bits), bits, len), codes);
    });
}

#[test]
fn prop_packing_bit_exact_at_ultra_low_widths() {
    // The serving path stores codes at 1/2/3 bits; packing must be an
    // exact bijection there for every length, including lengths that
    // leave a partial trailing byte and 3-bit codes straddling bytes.
    check("1/2/3-bit pack/unpack bit-exactness", |rng| {
        for bits in [1u32, 2, 3] {
            let len = rng.below(513);
            let codes: Vec<u8> =
                (0..len).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
            let packed = pack_codes(&codes, bits);
            assert_eq!(packed.len(), (len * bits as usize).div_ceil(8), "bits={bits}");
            assert_eq!(unpack_codes(&packed, bits, len), codes, "bits={bits} len={len}");
        }
    });
}

#[test]
fn prop_rtn_group_error_bound_holds_on_both_axes() {
    // RTN round-trip error must stay within one group scale no matter
    // which axis the grouping runs along (paper App. B: B' is quantized
    // column-wise by default, A' row-wise).
    check("rtn per-group bound, row and col axes", |rng| {
        let rows = 1 + rng.below(12);
        let cols = [24, 32, 50, 64][rng.below(4)];
        let std = rng.range_f32(0.2, 2.0);
        let w = rng.matrix(rows, cols, std);
        let bits = 1 + rng.below(4) as u32;
        let group = [8, 16, 32][rng.below(3)];
        for axis in [Axis::Row, Axis::Col] {
            let oriented = axis.orient(&w);
            let q = rtn_quant(&oriented, bits, group);
            let back = axis.restore(rtn_dequant(&q));
            assert_eq!(back.shape(), w.shape(), "{axis}");
            let gpr = q.groups_per_row();
            for i in 0..w.rows() {
                for j in 0..w.cols() {
                    // map the element to its (row, group) in quantization
                    // orientation to find the bounding scale
                    let (qi, qj) = match axis {
                        Axis::Row => (i, j),
                        Axis::Col => (j, i),
                    };
                    let s = q.scale[qi * gpr + qj / group].abs();
                    let e = (w.at(i, j) - back.at(i, j)).abs();
                    assert!(
                        e <= s * 1.01 + 1e-6,
                        "{axis} bits={bits} group={group} ({i},{j}): err {e} > scale {s}"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_factor_form_matches_materialized_oracle() {
    // The tentpole equivalence: applying a quantized adapter in factor
    // form on the activation path (x @ A′ᵀ @ B′ᵀ · s, packed factors
    // streamed through the fused dequant GEMMs) must match the dense
    // oracle `dequant_delta()` + explicit x @ ΔWᵀ within 1e-5 relative
    // Frobenius error — across 1/2/3-bit high sub-LoRAs, all four
    // quantization-axis combinations, and every low-mode ablation.
    check_with(Config { cases: 48, seed: 4242 }, "factor form == dense oracle", |rng| {
        let (m, n, r) = rand_dims(rng);
        let (b, a) = rng.lora_pair(m, n, r, rng.range_f32(0.4, 0.9));
        let bits = 1 + rng.below(3) as u32; // 1, 2, 3
        let axis = QuantAxis::all()[rng.below(4)];
        let low_mode = [LowMode::Bin, LowMode::Rtn1, LowMode::Prune][rng.below(3)];
        let cfg = LoraQuantConfig {
            bits_high: bits,
            axis,
            low_mode,
            hselect: HSelect::Ratio(rng.range_f32(0.3, 0.95)),
            group: [16, 32, 64][rng.below(3)],
            ste: None,
            ..Default::default()
        };
        let site = quantize_site(&b, &a, &cfg).unwrap();
        let scaling = rng.range_f32(0.5, 2.5);
        let rows = 1 + rng.below(6);
        let x = rng.matrix(rows, n, 1.0);
        // oracle: densify ΔW, merge-orientation apply x @ ΔWᵀ · s
        let oracle = matmul_a_bt(&x, &site.dequant_delta()).scale(scaling);
        // factor form: never densifies
        let mut y = Matrix::zeros(rows, m);
        site.factors().apply_delta_acc(x.data(), rows, scaling, y.data_mut());
        let err = y.rel_err(&oracle);
        assert!(
            err < 1e-5,
            "bits={bits} axis={axis} low={low_mode:?} group={}: rel err {err}",
            cfg.group
        );
        // and the materialized view agrees with the dequant oracle too
        assert!(site.factors().materialize_delta().rel_err(&site.dequant_delta()) < 1e-5);
    });
}

/// The PR-4 tentpole equivalence: KV-cached incremental decode
/// (`Engine::prefill` + `Engine::decode_step`, driven through
/// `decode_lockstep` by an `EngineStepper`) must be **token-identical**
/// to the full-recompute oracle — and its logits rows bit-identical
/// (stronger than the 1e-5 relative bound the design asks for) — across
/// batch sizes 1/2/4, ragged prompt lengths, random budgets (including
/// zero), 1/2/3-bit adapters, on both the merged-weights and
/// factor-form paths.
#[cfg(not(feature = "pjrt"))]
#[test]
fn prop_incremental_decode_matches_full_recompute_oracle() {
    use loraquant::eval::{decode_lockstep, EngineStepper, FullRecompute};
    use loraquant::loraquant::{QFactors, QuantizedLora};
    use loraquant::model::merge::quant_deltas;
    use loraquant::model::{merge_adapter, BaseWeights};
    use loraquant::runtime::{DeviceWeights, Engine};
    use loraquant::testutil::{synth_model_config, write_synth_model};

    let dir = std::env::temp_dir().join(format!("lq_prop_kv_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = synth_model_config();
    write_synth_model(&dir, "synth", &cfg, &[4], 4711).unwrap();
    let base = BaseWeights::load(dir.join("synth")).unwrap();
    let mut engine = Engine::new(&dir).unwrap();
    engine.load_model_fwd("synth", 4, base.cfg.param_names().len()).unwrap();
    let engine = engine;
    let w_base = engine
        .upload_weights(&merge_adapter(&base, &std::collections::BTreeMap::new()).unwrap())
        .unwrap();
    let (t_len, vocab) = (cfg.seq_len, cfg.vocab);

    check_with(Config { cases: 10, seed: 271828 }, "kv decode == full recompute", |rng| {
        // a fresh adapter covering every site at 1/2/3 bits
        let bits = 1 + rng.below(3) as u32;
        let qcfg = LoraQuantConfig {
            ste: None,
            group: 16,
            ..LoraQuantConfig::variant(bits, 0.9)
        };
        let mut q = QuantizedLora::default();
        for site in cfg.lora_site_names() {
            let short = site.rsplit_once('.').unwrap().1;
            let (n_in, m_out) = cfg.site_shape(short).unwrap();
            let (b, a) = rng.lora_pair(m_out, n_in, cfg.lora_rank, 0.7);
            q.sites.insert(site, quantize_site(&b, &a, &qcfg).unwrap());
        }
        let w_merged = engine
            .upload_weights(&merge_adapter(&base, &quant_deltas(&q)).unwrap())
            .unwrap();
        let qf = q.factors();

        // ragged prompts, random budgets (0 = lane never steps)
        let bsz = [1usize, 2, 4][rng.below(3)];
        let mut seqs = vec![vec![0i32; t_len]; bsz];
        let mut pos = vec![0usize; bsz];
        for k in 0..bsz {
            let plen = 1 + rng.below(6);
            for slot in seqs[k].iter_mut().take(plen) {
                *slot = 1 + rng.below(vocab - 1) as i32;
            }
            pos[k] = plen;
        }
        let budgets: Vec<usize> = (0..bsz).map(|_| rng.below(t_len)).collect();
        if budgets.iter().zip(&pos).all(|(&b, &p)| b.min(t_len - p) == 0) {
            return; // nothing decodes; trivially equal
        }

        for factor in [false, true] {
            let (w, adapters): (&DeviceWeights, Vec<Option<&QFactors>>) = if factor {
                (&w_base, (0..bsz).map(|_| Some(&qf)).collect())
            } else {
                (&w_merged, Vec::new())
            };
            // prefill logits row == the full forward's row at pos-1
            let (_, inc0) = engine.prefill("synth/b4", &seqs, &pos, w, &adapters).unwrap();
            let flat: Vec<i32> = seqs.iter().flatten().copied().collect();
            let full = engine
                .forward_with_adapters("synth/b4", &flat, &[bsz, t_len], w, &adapters)
                .unwrap();
            for k in 0..bsz {
                let want = &full[(k * t_len + pos[k] - 1) * vocab..(k * t_len + pos[k]) * vocab];
                assert_eq!(
                    &inc0[k * vocab..(k + 1) * vocab],
                    want,
                    "bits={bits} bsz={bsz} factor={factor} lane {k}: prefill row"
                );
            }
            // full greedy decode, both ways
            let (mut seqs_o, mut pos_o) = (seqs.clone(), pos.clone());
            let mut oracle = FullRecompute::new(t_len, vocab, |flat: &[i32]| {
                engine.forward_with_adapters("synth/b4", flat, &[bsz, t_len], w, &adapters)
            });
            let gen_o =
                decode_lockstep(t_len, vocab, &mut seqs_o, &mut pos_o, &budgets, &mut oracle)
                    .unwrap();
            let (mut seqs_i, mut pos_i) = (seqs.clone(), pos.clone());
            let mut stepper = EngineStepper::new(&engine, "synth/b4", w, &adapters);
            let gen_i =
                decode_lockstep(t_len, vocab, &mut seqs_i, &mut pos_i, &budgets, &mut stepper)
                    .unwrap();
            assert_eq!(gen_i, gen_o, "bits={bits} bsz={bsz} factor={factor}: tokens");
            assert_eq!(seqs_i, seqs_o, "bits={bits} bsz={bsz} factor={factor}: sequences");
            assert_eq!(pos_i, pos_o);
        }
    });
    let _ = std::fs::remove_dir_all(&dir);
}

/// The PR-5 tentpole equivalence: the continuous-batching scheduler
/// (`scheduler::run_continuous` over a `SessionStepper` — staggered
/// admissions into freed lanes of one warm session) must be
/// **token-identical** to the per-batch lock-step path for every
/// request, across lane counts 1/2/3, ragged prompts, random budgets
/// (including zero), 1/2/3-bit adapters, merged and factor paths, and
/// multi-tenant fair admission. The oracle decodes each request alone
/// through `decode_lockstep` — per-lane independence of the engine makes
/// that the exact expected output for any lane composition.
#[cfg(not(feature = "pjrt"))]
#[test]
fn prop_continuous_matches_lockstep_oracle() {
    use loraquant::eval::{decode_lockstep, EngineStepper, TOKENS};
    use loraquant::loraquant::{FactorSource, QFactors, QuantizedLora};
    use loraquant::model::merge::quant_deltas;
    use loraquant::model::{merge_adapter, BaseWeights};
    use loraquant::runtime::{DeviceWeights, Engine};
    use loraquant::scheduler::{
        run_continuous, AdmissionQueue, ContinuousConfig, LaneRequest, SessionStepper,
    };
    use loraquant::testutil::{synth_model_config, write_synth_model};
    use std::sync::Arc;
    use std::time::Instant;

    let dir = std::env::temp_dir().join(format!("lq_prop_sched_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = synth_model_config();
    write_synth_model(&dir, "synth", &cfg, &[4], 7321).unwrap();
    let base = BaseWeights::load(dir.join("synth")).unwrap();
    let mut engine = Engine::new(&dir).unwrap();
    engine.load_model_fwd("synth", 4, base.cfg.param_names().len()).unwrap();
    let engine = engine;
    let w_base = engine
        .upload_weights(&merge_adapter(&base, &std::collections::BTreeMap::new()).unwrap())
        .unwrap();
    let (t_len, vocab) = (cfg.seq_len, cfg.vocab);
    let clock = loraquant::clock::Clock::real();

    check_with(Config { cases: 10, seed: 816 }, "continuous == lockstep", |rng| {
        // a pool of quantized adapters at 1/2/3 bits (tenant i uses
        // adapter i % pool)
        let n_adapters = 1 + rng.below(3);
        let stored: Vec<Arc<QuantizedLora>> = (0..n_adapters)
            .map(|_| {
                let bits = 1 + rng.below(3) as u32;
                let qcfg = LoraQuantConfig {
                    ste: None,
                    group: 16,
                    ..LoraQuantConfig::variant(bits, 0.9)
                };
                let mut q = QuantizedLora::default();
                for site in cfg.lora_site_names() {
                    let short = site.rsplit_once('.').unwrap().1;
                    let (n_in, m_out) = cfg.site_shape(short).unwrap();
                    let (b, a) = rng.lora_pair(m_out, n_in, cfg.lora_rank, 0.7);
                    q.sites.insert(site, quantize_site(&b, &a, &qcfg).unwrap());
                }
                Arc::new(q)
            })
            .collect();
        // merged weights for the merged-path variant (single tenant 0)
        let w_merged = engine
            .upload_weights(&merge_adapter(&base, &quant_deltas(&stored[0])).unwrap())
            .unwrap();

        // staggered request mix: ragged prompts, random budgets (0 ok)
        let n_reqs = 1 + rng.below(7);
        let prompts: Vec<Vec<i32>> = (0..n_reqs)
            .map(|_| {
                let plen = 1 + rng.below(6);
                (0..plen).map(|_| 1 + rng.below(vocab - 1) as i32).collect()
            })
            .collect();
        let budgets: Vec<usize> = (0..n_reqs).map(|_| rng.below(8)).collect();
        let lanes = [1usize, 2, 3][rng.below(3)];

        for (factor, chunk) in [(false, 0usize), (false, 2), (true, 0), (true, 2)] {
            let w: &DeviceWeights = if factor { &w_base } else { &w_merged };
            let mut queue = AdmissionQueue::new();
            for i in 0..n_reqs {
                queue.push(LaneRequest {
                    id: i as u64,
                    tenant: (i % n_adapters) as u32,
                    prompt: prompts[i].clone(),
                    budget: budgets[i],
                    adapter: factor.then(|| {
                        let src: Arc<dyn FactorSource> = Arc::clone(&stored[i % n_adapters]);
                        src
                    }),
                    enqueued: Instant::now(),
                });
            }
            let mut slot = None;
            let mut stepper = SessionStepper::new(&engine, "synth/b4", w, &mut slot);
            let ccfg =
                ContinuousConfig { lanes, seq_len: t_len, vocab, prefill_chunk: chunk };
            let mut got: Vec<Option<Vec<i32>>> = vec![None; n_reqs];
            let stats =
                run_continuous(&mut stepper, &ccfg, &mut queue, &clock, |fin| {
                    got[fin.id as usize] = Some(fin.tokens);
                })
                .unwrap();
            assert_eq!(stats.finished as usize, n_reqs, "factor={factor} chunk={chunk}");
            assert!(stats.peak_lanes <= lanes);

            // oracle: each request decoded alone, lock-step
            for i in 0..n_reqs {
                let qf: QFactors;
                let adapters: Vec<Option<&QFactors>> = if factor {
                    qf = stored[i % n_adapters].factors();
                    vec![Some(&qf)]
                } else {
                    Vec::new()
                };
                let mut seqs = vec![vec![TOKENS::PAD; t_len]];
                seqs[0][..prompts[i].len()].copy_from_slice(&prompts[i]);
                let mut pos = vec![prompts[i].len()];
                let mut oracle = EngineStepper::new(&engine, "synth/b4", w, &adapters);
                let want = decode_lockstep(
                    t_len,
                    vocab,
                    &mut seqs,
                    &mut pos,
                    &[budgets[i]],
                    &mut oracle,
                )
                .unwrap()
                .remove(0);
                assert_eq!(
                    got[i].as_deref(),
                    Some(&want[..]),
                    "factor={factor} chunk={chunk} lanes={lanes} request {i}: \
                     continuous vs lock-step"
                );
            }
        }
    });
    let _ = std::fs::remove_dir_all(&dir);
}

/// The PR-7 tentpole equivalence: chunked prefill
/// (`Engine::prefill_chunk`) must leave a **bit-identical**
/// `DecodeState` — full KV buffers, consumed lengths, next-token
/// logits — to the monolithic admission (`Engine::admit`) of the same
/// prompt, across chunk sizes {1, 32, 128, >prompt} × compute threads
/// {1, 2, 4} × merged/factor paths × 1/2/3-bit adapters. Every
/// non-attention kernel is row-local and an attention row reads only
/// its own lane's earlier cache columns, so chunking changes *when*
/// rows are computed, never *what* any row reads (DESIGN.md §13).
#[cfg(not(feature = "pjrt"))]
#[test]
fn prop_chunked_prefill_matches_monolithic_prefill() {
    use loraquant::loraquant::{FactorSource, QuantizedLora};
    use loraquant::model::merge::quant_deltas;
    use loraquant::model::{merge_adapter, BaseWeights};
    use loraquant::runtime::Engine;
    use loraquant::testutil::{synth_model_config, write_synth_model};
    use std::sync::Arc;

    let dir = std::env::temp_dir().join(format!("lq_prop_chunk_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // a longer sequence than the default synth shape, so the 32- and
    // 128-token chunk sizes are genuine mid-prompt slices
    let mut cfg = synth_model_config();
    cfg.seq_len = 160;
    write_synth_model(&dir, "synth", &cfg, &[2], 4177).unwrap();
    let base = BaseWeights::load(dir.join("synth")).unwrap();
    let mut engine = Engine::new(&dir).unwrap();
    engine.load_model_fwd("synth", 2, base.cfg.param_names().len()).unwrap();
    let w_base = engine
        .upload_weights(&merge_adapter(&base, &std::collections::BTreeMap::new()).unwrap())
        .unwrap();

    let mut rng = Rng::new(90210);
    let prompt: Vec<i32> = (0..150).map(|_| 1 + rng.below(cfg.vocab - 1) as i32).collect();
    let lane = 1usize; // a non-zero lane so lane-offset bugs cannot hide

    for bits in [1u32, 2, 3] {
        let qcfg =
            LoraQuantConfig { ste: None, group: 16, ..LoraQuantConfig::variant(bits, 0.9) };
        let mut q = QuantizedLora::default();
        for site in cfg.lora_site_names() {
            let short = site.rsplit_once('.').unwrap().1;
            let (n_in, m_out) = cfg.site_shape(short).unwrap();
            let (b, a) = rng.lora_pair(m_out, n_in, cfg.lora_rank, 0.7);
            q.sites.insert(site, quantize_site(&b, &a, &qcfg).unwrap());
        }
        let stored = Arc::new(q);
        let w_merged = engine
            .upload_weights(&merge_adapter(&base, &quant_deltas(&stored)).unwrap())
            .unwrap();
        for factor in [false, true] {
            let w = if factor { &w_base } else { &w_merged };
            // the monolithic oracle, single-threaded
            engine.set_compute_threads(1);
            let mut oracle = engine.new_session("synth/b2", 2, w).unwrap();
            if factor {
                let src: Arc<dyn FactorSource> = Arc::clone(&stored) as _;
                oracle.bind_adapter(lane, Some(src)).unwrap();
            }
            engine.admit(&mut oracle, &[lane], &[&prompt], w, &[]).unwrap();
            let bits_of = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            let want_k = bits_of(oracle.kv_cache().keys());
            let want_v = bits_of(oracle.kv_cache().values());
            let want_lens = [oracle.lane_len(0), oracle.lane_len(1)];
            let want_logits = bits_of(oracle.lane_logits(lane));

            for threads in [1usize, 2, 4] {
                engine.set_compute_threads(threads);
                for chunk in [1usize, 32, 128, 256] {
                    let tag = format!("bits={bits} factor={factor} threads={threads} chunk={chunk}");
                    let mut st = engine.new_session("synth/b2", 2, w).unwrap();
                    if factor {
                        let src: Arc<dyn FactorSource> = Arc::clone(&stored) as _;
                        st.bind_adapter(lane, Some(src)).unwrap();
                    }
                    let mut start = 0usize;
                    while start < prompt.len() {
                        let end = (start + chunk).min(prompt.len());
                        let last = end == prompt.len();
                        engine
                            .prefill_chunk(&mut st, lane, &prompt[start..end], start, last, w, &[])
                            .unwrap();
                        assert_eq!(st.is_prefilling(lane), !last, "{tag} at {start}");
                        assert_eq!(st.is_retired(lane), !last, "{tag} at {start}");
                        start = end;
                    }
                    assert_eq!(bits_of(st.kv_cache().keys()), want_k, "{tag}: K cache");
                    assert_eq!(bits_of(st.kv_cache().values()), want_v, "{tag}: V cache");
                    assert_eq!([st.lane_len(0), st.lane_len(1)], want_lens, "{tag}: lens");
                    assert_eq!(bits_of(st.lane_logits(lane)), want_logits, "{tag}: logits");
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The ISSUE-8 codec contract: the at-rest store is a *lossless* codec
/// for quantized adapters — packed codes, scales and zero points survive
/// encode → decode bit-for-bit across every low mode × 1/2/3-bit high
/// parts × all four quantization-axis pairs × ratio/static rank splits
/// (including `h == r`, where no low parts exist at all). Pinned three
/// ways: the dequantized delta is bit-identical, storage accounting is
/// unchanged, and re-encoding the decoded adapter reproduces the exact
/// tensor map — so a decode bug cannot hide behind a mirror-image
/// encode bug. The disk tier (DESIGN.md §14) leans on this: tiered
/// serving is bit-equal to resident serving only because this codec is.
#[test]
fn prop_store_codec_roundtrip_is_bit_exact() {
    use loraquant::adapter::store;
    check_with(Config { cases: 48, seed: 1808 }, "store encode/decode bit-exact", |rng| {
        let (m, n, r) = rand_dims(rng);
        let (b, a) = rng.lora_pair(m, n, r, rng.range_f32(0.4, 0.9));
        let bits = 1 + rng.below(3) as u32; // 1, 2, 3
        let low_mode = [LowMode::Bin, LowMode::Rtn1, LowMode::Prune][rng.below(3)];
        let hselect = if rng.below(2) == 0 {
            HSelect::Ratio(rng.range_f32(0.3, 0.95))
        } else {
            HSelect::Static(1 + rng.below(r))
        };
        let cfg = LoraQuantConfig {
            bits_high: bits,
            axis: QuantAxis::all()[rng.below(4)],
            low_mode,
            hselect,
            group: [16, 32, 64][rng.below(3)],
            ste: None,
            ..Default::default()
        };
        let mut q = QuantizedLora::default();
        q.sites.insert("l0.wq".into(), quantize_site(&b, &a, &cfg).unwrap());
        let enc = store::encode(&q).unwrap();
        let dec = store::decode(&enc).unwrap();
        let tag = format!("bits={bits} low={low_mode:?} hselect={hselect:?}");
        assert_eq!(dec.storage_bits(), q.storage_bits(), "{tag}: storage bits");
        let d0 = q.sites["l0.wq"].dequant_delta();
        let d1 = dec.sites["l0.wq"].dequant_delta();
        assert_eq!(d0.shape(), d1.shape(), "{tag}: delta shape");
        for (i, (x, y)) in d0.data().iter().zip(d1.data()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}: delta[{i}] {x:e} vs {y:e}");
        }
        assert_eq!(store::encode(&dec).unwrap(), enc, "{tag}: re-encode fixpoint");
    });
}

#[test]
fn prop_avg_bits_between_low_and_high() {
    // Mixed precision must land between pure-1-bit and pure-k-bit costs.
    check_with(Config { cases: 24, seed: 99 }, "avg bits sandwich", |rng| {
        let (m, n, r) = rand_dims(rng);
        let (b, a) = rng.lora_pair(m, n, r, 0.7);
        let bits = 2 + rng.below(2) as u32;
        let cfg = LoraQuantConfig {
            ste: None,
            ..LoraQuantConfig::variant(bits, rng.range_f32(0.3, 0.99))
        };
        let site = quantize_site(&b, &a, &cfg).unwrap();
        let ab = site.avg_bits();
        assert!(ab >= 1.0, "{ab}");
        // + scale overhead can push slightly past bits for tiny groups
        assert!(ab <= bits as f64 + 1.5, "{ab}");
    });
}

#[test]
fn prop_dynamic_h_monotone_in_rho() {
    check_with(Config { cases: 24, seed: 5 }, "h(rho) monotone", |rng| {
        let (m, n, r) = rand_dims(rng);
        let (b, a) = rng.lora_pair(m, n, r, 0.6);
        let rp = reparameterize(&b, &a);
        let mut prev = 0usize;
        for k in 1..=10 {
            let h = select_h(&rp.s, HSelect::Ratio(k as f32 * 0.1));
            assert!(h >= prev, "rho={} h={h} prev={prev}", k as f32 * 0.1);
            prev = h;
        }
    });
}

#[test]
fn prop_batcher_never_mixes_or_drops() {
    use loraquant::coordinator::{BatcherConfig, DynamicBatcher, PendingRequest};
    use std::time::{Duration, Instant};
    check_with(Config { cases: 48, seed: 31 }, "batcher conservation", |rng| {
        let bucket = 1 + rng.below(8);
        let mut b = DynamicBatcher::new(BatcherConfig {
            bucket,
            max_wait: Duration::from_millis(0),
            ..Default::default()
        });
        let t0 = Instant::now();
        let n = rng.below(64);
        let mut per_adapter = std::collections::BTreeMap::new();
        for i in 0..n {
            let adapter = rng.below(5) as u32;
            *per_adapter.entry(adapter).or_insert(0usize) += 1;
            b.push(PendingRequest { adapter, enqueued: t0, payload: i });
        }
        let mut got = std::collections::BTreeMap::new();
        while let Some(batch) = b.pop_ready(t0 + Duration::from_secs(1)) {
            assert!(batch.requests.len() <= bucket);
            let id = batch.adapter.expect("per-adapter mode always tags batches");
            assert!(batch.requests.iter().all(|r| r.adapter == id));
            *got.entry(id).or_insert(0usize) += batch.requests.len();
        }
        assert_eq!(got, per_adapter, "every request must be released exactly once");
        assert_eq!(b.pending(), 0);
    });
}

#[test]
fn prop_lru_respects_budget_and_conserves_bytes() {
    use loraquant::coordinator::LruCache;
    check_with(Config { cases: 48, seed: 77 }, "lru byte accounting", |rng| {
        let budget = 50 + rng.below(100);
        let mut c: LruCache<u32, u32> = LruCache::new(budget);
        for i in 0..rng.below(40) {
            let k = rng.below(12) as u32;
            let bytes = 1 + rng.below(30);
            c.insert(k, i as u32, bytes);
            assert!(c.used_bytes() <= budget.max(bytes), "over budget");
            assert!(c.len() >= 1);
        }
    });
}

#[test]
fn prop_rouge_l_bounds_and_identity() {
    use loraquant::eval::rouge_l;
    check("rouge-l in [0,1], 1 iff equal-enough", |rng| {
        let n = 1 + rng.below(10);
        let a: Vec<i32> = (0..n).map(|_| rng.below(8) as i32).collect();
        let b: Vec<i32> = (0..1 + rng.below(10)).map(|_| rng.below(8) as i32).collect();
        let f = rouge_l(&a, &b);
        assert!((0.0..=1.0).contains(&f));
        assert_eq!(rouge_l(&a, &a), 1.0);
        // symmetry of F1
        assert!((f - rouge_l(&b, &a)).abs() < 1e-12);
    });
}

// ---------------------------------------------------------------------------
// PR-6 kernel determinism contract: the blocked/SIMD kernels must be
// bit-identical to the naive scalar oracles in `tensor::scalar`, for any
// shape (k/n not multiples of the 8-wide lane or 4-row p-block) and for
// any operand bits — including NaN, ±inf and -0.0, which the old
// skip-branch kernels silently swallowed.
// ---------------------------------------------------------------------------

/// Bitwise slice equality, NaN-tolerant: any NaN payload matches any
/// other (the op sequences are identical, but we don't pin payloads).
fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits() || (g.is_nan() && w.is_nan()),
            "{what}: bit mismatch at {i}: {g:e} ({:#010x}) vs {w:e} ({:#010x})",
            g.to_bits(),
            w.to_bits()
        );
    }
}

/// Mostly-normal values salted with the IEEE hazard set.
fn hazard_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len)
        .map(|_| match rng.below(16) {
            0 => 0.0,
            1 => -0.0,
            2 => f32::NAN,
            3 => f32::INFINITY,
            4 => f32::NEG_INFINITY,
            _ => rng.normal(),
        })
        .collect()
}

#[test]
fn prop_blocked_gemm_bit_identical_to_scalar_oracle() {
    use loraquant::tensor::{matmul_flat, scalar};
    check("blocked matmul_flat == scalar oracle (bitwise)", |rng| {
        let m = rng.range(1, 9);
        let k = rng.range(1, 30);
        let n = rng.range(1, 30);
        let a = hazard_vec(rng, m * k);
        let b = hazard_vec(rng, k * n);
        let mut got = vec![0.0f32; m * n];
        let mut want = vec![0.0f32; m * n];
        matmul_flat(&a, m, k, &b, n, &mut got);
        scalar::matmul_flat(&a, m, k, &b, n, &mut want);
        assert_bits_eq(&got, &want, &format!("matmul_flat {m}x{k}x{n}"));
    });
}

#[test]
fn prop_dot_bit_identical_to_canonical_scalar_order() {
    use loraquant::tensor::{dot, scalar};
    check("simd dot8 == canonical scalar order (bitwise)", |rng| {
        let len = rng.range(1, 67);
        let a = hazard_vec(rng, len);
        let b = hazard_vec(rng, len);
        let got = dot(&a, &b);
        let want = scalar::dot(&a, &b);
        assert!(
            got.to_bits() == want.to_bits() || (got.is_nan() && want.is_nan()),
            "dot len {len}: {got:e} vs {want:e}"
        );
    });
}

#[test]
fn prop_qdequant_gemms_bit_identical_across_bitwidths() {
    use loraquant::tensor::{matmul_qdequant_acc, matmul_qdequant_bt_acc, scalar};
    check_with(Config { cases: 32, seed: 6006 }, "qdequant acc/bt == scalar oracle", |rng| {
        let rows = rng.range(1, 6);
        let k = rng.range(1, 20);
        // Odd n so 3-bit packed rows straddle byte boundaries.
        let n = 2 * rng.below(10) + 1;
        let group = [3, 8, 16][rng.below(3)];
        let x = hazard_vec(rng, rows * k);
        let alpha = rng.range_f32(-2.0, 2.0);
        for bits in [1u32, 2, 3, 8] {
            let q = rtn_quant(&rng.matrix(k, n, 1.0), bits, group);
            let mut got = vec![0.5f32; rows * n]; // non-zero init: acc semantics
            let mut want = got.clone();
            matmul_qdequant_acc(&x, rows, k, &q, alpha, &mut got);
            scalar::matmul_qdequant_acc(&x, rows, k, &q, alpha, &mut want);
            assert_bits_eq(&got, &want, &format!("qdequant_acc bits={bits}"));

            let qt = rtn_quant(&rng.matrix(n, k, 1.0), bits, group);
            let mut got = vec![-0.5f32; rows * n];
            let mut want = got.clone();
            matmul_qdequant_bt_acc(&x, rows, k, &qt, alpha, &mut got);
            scalar::matmul_qdequant_bt_acc(&x, rows, k, &qt, alpha, &mut want);
            assert_bits_eq(&got, &want, &format!("qdequant_bt_acc bits={bits}"));
        }
        // The sign quantizer drives the same kernels through BinQuantized.
        let qb = bin_quant(&rng.matrix(k, n, 1.0), group);
        let mut got = vec![0.0f32; rows * n];
        let mut want = got.clone();
        matmul_qdequant_acc(&x, rows, k, &qb, alpha, &mut got);
        scalar::matmul_qdequant_acc(&x, rows, k, &qb, alpha, &mut want);
        assert_bits_eq(&got, &want, "qdequant_acc binary");

        let qbt = bin_quant(&rng.matrix(n, k, 1.0), group);
        let mut got = vec![0.0f32; rows * n];
        let mut want = got.clone();
        matmul_qdequant_bt_acc(&x, rows, k, &qbt, alpha, &mut got);
        scalar::matmul_qdequant_bt_acc(&x, rows, k, &qbt, alpha, &mut want);
        assert_bits_eq(&got, &want, "qdequant_bt_acc binary");
    });
}

#[test]
fn prop_lut_unpack_range_matches_full_unpack_at_any_offset() {
    use loraquant::quant::unpack_codes_range;
    check("LUT range unpack == full-unpack slice at odd starts", |rng| {
        let bits = rng.range(1, 9) as u32;
        let total = rng.range(1, 80);
        let codes: Vec<u8> = (0..total).map(|_| rng.below(1usize << bits) as u8).collect();
        let packed = pack_codes(&codes, bits);
        let full = unpack_codes(&packed, bits, total);
        assert_eq!(full, codes, "full roundtrip bits={bits} total={total}");
        // Arbitrary (start, count) windows exercise the scalar prefix,
        // the LUT-group body, and the scalar tail — including 3-bit
        // groups that straddle byte boundaries at odd starts.
        let start = rng.below(total);
        let count = rng.below(total - start + 1);
        let part = unpack_codes_range(&packed, bits, start, count);
        assert_eq!(part, &full[start..start + count], "bits={bits} start={start} count={count}");
    });
}

#[test]
fn prop_pool_matmul_bit_identical_at_every_thread_count() {
    use loraquant::scheduler::ComputePool;
    use loraquant::tensor::scalar;
    check_with(Config { cases: 16, seed: 909 }, "pool matmul == scalar at 1/2/4 threads", |rng| {
        let m = rng.range(1, 10);
        let k = rng.range(1, 24);
        let n = rng.range(1, 24);
        let a = hazard_vec(rng, m * k);
        let b = hazard_vec(rng, k * n);
        let mut want = vec![0.0f32; m * n];
        scalar::matmul_flat(&a, m, k, &b, n, &mut want);
        for t in [1usize, 2, 4] {
            let pool = ComputePool::new(t);
            let mut got = vec![0.0f32; m * n];
            pool.matmul_flat(&a, m, k, &b, n, &mut got).unwrap();
            assert_bits_eq(&got, &want, &format!("pool threads={t} {m}x{k}x{n}"));
        }
    });
}

/// §15 cancellation containment: a request whose cancel token is set
/// retires with a structured `Cancelled` before claiming a lane, and —
/// the containment half — never perturbs anyone else: every surviving
/// request decodes bit-identically to a cancel-free run of the same
/// trace. Random adapter mixes, budgets, and cancel masks; runs on the
/// real clock, so the property is also timing-robust (per-lane
/// independence, not schedule luck).
#[cfg(not(feature = "pjrt"))]
#[test]
fn prop_cancellation_leaves_survivors_bit_identical() {
    use loraquant::coordinator::{Coordinator, CoordinatorConfig, FailKind, GenRequest};
    use loraquant::testutil::{synth_model_config, synth_quantized_adapter, write_synth_model};
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;
    use std::time::Duration;

    let dir = std::env::temp_dir().join(format!("lq_prop_cancel_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = synth_model_config();
    write_synth_model(&dir, "synth", &cfg, &[1, 4], 42).unwrap();

    let start = |dir: &std::path::Path| {
        let mut c = CoordinatorConfig::new(dir, "synth").with_workers(1).with_buckets(vec![1, 4]);
        c.max_wait = Duration::from_millis(1);
        Coordinator::start(c).expect("coordinator start")
    };
    check_with(Config { cases: 6, seed: 0xCA9CE1 }, "cancelled requests leave no trace", |rng| {
        // a per-case request plan: (adapter index, budget, cancelled?)
        let n = 8 + rng.below(5);
        let mut plan: Vec<(usize, usize, bool)> =
            (0..n).map(|_| (rng.below(2), 1 + rng.below(3), rng.below(3) == 0)).collect();
        if plan.iter().all(|&(.., c)| !c) {
            plan[0].2 = true; // at least one cancellation per case
        }
        let run = |cancels_armed: bool| {
            let (coord, join) = start(&dir);
            let ids = [
                coord.register_adapter(synth_quantized_adapter(&cfg, 900), "a").unwrap(),
                coord.register_adapter(synth_quantized_adapter(&cfg, 901), "b").unwrap(),
            ];
            let rxs: Vec<_> = plan
                .iter()
                .map(|&(a, budget, cancelled)| {
                    let mut req = GenRequest::new(ids[a], vec![1, 5, 4, 7, 3], budget);
                    if cancels_armed && cancelled {
                        // pre-flipped: the scheduler must observe it at
                        // admission, before the request claims a lane
                        req = req.with_cancel(Arc::new(AtomicBool::new(true)));
                    }
                    coord.generate_async(req)
                })
                .collect();
            let results: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
            coord.shutdown();
            join.join().unwrap();
            results
        };
        let faulted = run(true);
        let clean = run(false);
        for (i, (&(.., cancelled), (got, want))) in
            plan.iter().zip(faulted.iter().zip(&clean)).enumerate()
        {
            let want = want.as_ref().expect("clean run completes every request");
            if cancelled {
                let err = got.as_ref().expect_err("pre-cancelled request must not complete");
                assert_eq!(err.kind, FailKind::Cancelled, "req {i}: {err}");
            } else {
                let got = got.as_ref().expect("survivor must complete");
                assert_eq!(got.tokens, want.tokens, "req {i}: survivor tokens must be bit-identical");
            }
        }
    });
}
