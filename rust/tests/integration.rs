//! Cross-module integration tests: quantization pipeline ↔ serialization ↔
//! baselines ↔ registry, on realistic adapter shapes (no PJRT needed).

use loraquant::adapter::{store, LoraAdapter};
use loraquant::baselines::{BiLlm, FlatQuantizer, Gptq, JdDiagonal, PbLlm, Quantizer};
use loraquant::coordinator::{AdapterRegistry, StoredAdapter};
use loraquant::loraquant::{
    quantize_site, HSelect, LoraQuantConfig, LowMode, QuantizedLora, SplitStrategy,
};
use loraquant::tensor::matmul;
use loraquant::testutil::Rng;

/// All transformer site shapes of tiny-llama-s.
const SITES: [(&str, usize, usize); 3] = [("wq", 128, 128), ("w1", 512, 128), ("w2", 128, 512)];

fn build_adapter(seed: u64) -> (LoraAdapter, QuantizedLora) {
    let mut rng = Rng::new(seed);
    let mut fp = LoraAdapter::default();
    let mut q = QuantizedLora::default();
    for (name, m, n) in SITES {
        let (b, a) = rng.lora_pair(m, n, 16, 0.7);
        q.sites.insert(format!("l0.{name}"), quantize_site(&b, &a, &LoraQuantConfig::default()).unwrap());
        fp.sites.insert(format!("l0.{name}"), (a, b));
    }
    (fp, q)
}

#[test]
fn pipeline_to_disk_to_registry() {
    let (fp, q) = build_adapter(1);
    // serialize + reload
    let tmp = std::env::temp_dir().join("lq_integration_adapter.bin");
    store::save(&tmp, &q).unwrap();
    let q2 = store::load(&tmp).unwrap();
    std::fs::remove_file(&tmp).ok();
    assert_eq!(q2.storage_bits(), q.storage_bits());
    // registry accounting: quantized much smaller than fp16
    let mut reg = AdapterRegistry::new();
    let id_fp = reg.register(StoredAdapter::Fp16(fp), "t");
    let id_q = reg.register(StoredAdapter::Quantized(q2), "t");
    let fp_bytes = reg.get(id_fp).unwrap().bytes();
    let q_bytes = reg.get(id_q).unwrap().bytes();
    assert!(q_bytes * 5 < fp_bytes, "quantized {q_bytes} vs fp {fp_bytes}");
    // deltas from both paths have matching shapes
    let d_fp = reg.get(id_fp).unwrap().resident().unwrap().deltas();
    let d_q = reg.get(id_q).unwrap().resident().unwrap().deltas();
    for (site, m) in &d_fp {
        assert_eq!(m.shape(), d_q[site].shape());
    }
}

#[test]
fn loraquant_beats_flat_baselines_at_lower_bits() {
    // The Table-1 headline in weight space, across all site shapes.
    let mut rng = Rng::new(2);
    for (name, m, n) in SITES {
        let (b, a) = rng.lora_pair(m, n, 16, 0.65);
        let ba = matmul(&b, &a);
        let site = quantize_site(
            &b,
            &a,
            &LoraQuantConfig { group: 128, ..LoraQuantConfig::variant(2, 0.9) },
        )
        .unwrap();
        let e_lq = site.dequant_delta().rel_err(&ba);
        let bin = FlatQuantizer::bin(128).quantize(&b, &a, None);
        let rtn1 = FlatQuantizer::rtn(1, 128).quantize(&b, &a, None);
        assert!(site.avg_bits() < 2.0, "{name}: {}", site.avg_bits());
        assert!(
            e_lq < bin.dequant_delta().rel_err(&ba),
            "{name}: loraquant must beat BIN"
        );
        assert!(
            e_lq < rtn1.dequant_delta().rel_err(&ba),
            "{name}: loraquant must beat RTN-1"
        );
    }
}

#[test]
fn method_error_ordering_matches_paper_shape() {
    // RTN1 worst, BIN bad, 2-bit methods better, LoRAQuant-3 best of the
    // ultra-low group — weight-space proxy of Table 1's ordering.
    let mut rng = Rng::new(3);
    let (b, a) = rng.lora_pair(256, 128, 16, 0.7);
    let ba = matmul(&b, &a);
    let err = |d: loraquant::tensor::Matrix| d.rel_err(&ba);
    let e_rtn1 = err(FlatQuantizer::rtn(1, 128).quantize(&b, &a, None).dequant_delta());
    let e_bin = err(FlatQuantizer::bin(128).quantize(&b, &a, None).dequant_delta());
    let e_rtn2 = err(FlatQuantizer::rtn(2, 128).quantize(&b, &a, None).dequant_delta());
    let e_pb = err(PbLlm::default().quantize(&b, &a, None).dequant_delta());
    let e_bi = err(BiLlm::default().quantize(&b, &a, None).dequant_delta());
    let lq3 = quantize_site(&b, &a, &LoraQuantConfig { group: 128, ..LoraQuantConfig::variant(3, 0.9) })
        .unwrap();
    let e_lq3 = err(lq3.dequant_delta());
    assert!(e_bin < e_rtn1, "bin {e_bin} < rtn1 {e_rtn1}");
    assert!(e_rtn2 < e_bin, "rtn2 {e_rtn2} < bin {e_bin}");
    assert!(e_pb < e_bin && e_bi < e_bin);
    assert!(e_lq3 < e_rtn2, "lq3 {e_lq3} < rtn2 {e_rtn2}");
}

#[test]
fn gptq_with_calibration_runs_on_all_shapes() {
    let mut rng = Rng::new(4);
    for (_, m, n) in SITES {
        let (b, a) = rng.lora_pair(m, n, 16, 0.7);
        let calib = rng.matrix(64, n, 1.0);
        let c = Gptq::new(2, 128).quantize(&b, &a, Some(&calib));
        assert_eq!(c.dequant_delta().shape(), (m, n));
        assert!(c.avg_bits() > 2.0 && c.avg_bits() < 4.0);
    }
}

#[test]
fn jd_diagonal_cluster_of_three_tasks() {
    let mut rng = Rng::new(5);
    let pairs: Vec<_> = (0..3).map(|_| rng.lora_pair(128, 128, 16, 0.6)).collect();
    let cluster = JdDiagonal { k: 16 }.fit(&pairs);
    assert!((cluster.avg_bits() - 16.0 / 3.0).abs() < 0.2, "{}", cluster.avg_bits());
    for (i, (b, a)) in pairs.iter().enumerate() {
        let err = cluster.dequant_delta(i).rel_err(&matmul(b, a));
        assert!(err < 1.0);
    }
}

#[test]
fn every_low_mode_roundtrips_through_store() {
    let mut rng = Rng::new(6);
    let (b, a) = rng.lora_pair(64, 64, 8, 0.7);
    for low_mode in [LowMode::Bin, LowMode::Rtn1, LowMode::Prune] {
        let cfg = LoraQuantConfig { low_mode, ste: None, ..Default::default() };
        let mut q = QuantizedLora::default();
        q.sites.insert("s".into(), quantize_site(&b, &a, &cfg).unwrap());
        let dec = store::decode(&store::encode(&q).unwrap()).unwrap();
        assert!(
            dec.sites["s"].dequant_delta().sub(&q.sites["s"].dequant_delta()).fro_norm() < 1e-6,
            "{low_mode:?}"
        );
    }
}

#[test]
fn split_strategies_consistent_with_static_h() {
    let mut rng = Rng::new(7);
    let (b, a) = rng.lora_pair(96, 96, 16, 0.6);
    let ba = matmul(&b, &a);
    let mut errs = Vec::new();
    for strategy in [SplitStrategy::Svd, SplitStrategy::Norm, SplitStrategy::Random { seed: 5 }] {
        let cfg = LoraQuantConfig {
            strategy,
            hselect: HSelect::Static(6),
            ste: None,
            ..Default::default()
        };
        let site = quantize_site(&b, &a, &cfg).unwrap();
        assert_eq!(site.h, 6);
        errs.push(site.dequant_delta().rel_err(&ba));
    }
    // Fig. 2 shape: svd <= norm <= random (allowing small noise)
    assert!(errs[0] <= errs[1] * 1.05, "svd {} vs norm {}", errs[0], errs[1]);
    assert!(errs[0] <= errs[2] * 1.05, "svd {} vs random {}", errs[0], errs[2]);
}
