//! Coordinator serving tests against the real PJRT runtime (skipped with a
//! notice when `make artifacts` hasn't produced the model yet).

use loraquant::adapter::LoraAdapter;
use loraquant::coordinator::{Coordinator, CoordinatorConfig, GenRequest, StoredAdapter};
use loraquant::loraquant::{quantize_site, LoraQuantConfig, QuantizedLora};
use std::path::Path;
use std::time::Duration;

const MODEL: &str = "tiny-llama-s";

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    (p.join(MODEL).join("base.bin").exists()
        && p.join(format!("{MODEL}.fwd.b8.hlo.txt")).exists())
    .then_some(p)
}

fn start() -> Option<(Coordinator, std::thread::JoinHandle<()>)> {
    let dir = artifacts()?;
    let mut cfg = CoordinatorConfig::new(dir, MODEL);
    cfg.max_wait = Duration::from_millis(2);
    Some(Coordinator::start(cfg).expect("coordinator start"))
}

fn quantized_adapter(dir: &Path, task: &str) -> StoredAdapter {
    let lora = LoraAdapter::load(dir.join(MODEL).join(format!("{task}.lora.bin"))).unwrap();
    let mut q = QuantizedLora::default();
    for (site, (a, b)) in &lora.sites {
        q.sites.insert(site.clone(), quantize_site(b, a, &LoraQuantConfig::variant(2, 0.9)));
    }
    StoredAdapter::Quantized(q)
}

#[test]
fn serves_requests_and_reports_metrics() {
    let Some((coord, join)) = start() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let dir = artifacts().unwrap();
    let id = coord.register_adapter(quantized_adapter(dir, "modadd"), "modadd").unwrap();
    // BOS d5 MARK d7 SEP — ask for 2 answer tokens
    let resp = coord
        .generate(GenRequest { adapter: id, prompt: vec![1, 10, 4, 12, 3], max_new: 2 })
        .unwrap();
    assert_eq!(resp.tokens.len(), 2);
    assert!(resp.tokens.iter().all(|&t| (0..64).contains(&t)));
    let (m, cache, nreg) = coord.metrics().unwrap();
    assert_eq!(m.requests, 1);
    assert_eq!(nreg, 1);
    assert_eq!(cache.misses, 1, "first request must be a cache miss");
    coord.shutdown();
    join.join().unwrap();
}

#[test]
fn unknown_adapter_is_rejected() {
    let Some((coord, join)) = start() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let err = coord
        .generate(GenRequest { adapter: 999, prompt: vec![1, 3], max_new: 1 })
        .unwrap_err();
    assert!(err.to_string().contains("unknown adapter"));
    coord.shutdown();
    join.join().unwrap();
}

#[test]
fn batching_groups_by_adapter_and_caches_weights() {
    let Some((coord, join)) = start() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let dir = artifacts().unwrap();
    let id0 = coord.register_adapter(quantized_adapter(dir, "modadd"), "modadd").unwrap();
    let id1 = coord.register_adapter(quantized_adapter(dir, "transform"), "transform").unwrap();
    let mut rxs = Vec::new();
    for i in 0..16 {
        let adapter = if i % 2 == 0 { id0 } else { id1 };
        rxs.push(coord.generate_async(GenRequest {
            adapter,
            prompt: vec![1, 10, 4, 12, 3],
            max_new: 2,
        }));
    }
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let (m, cache, _) = coord.metrics().unwrap();
    assert_eq!(m.requests, 16);
    assert!(m.batches < 16, "requests must be batched ({} batches)", m.batches);
    assert_eq!(cache.misses, 2, "one merge per adapter");
    // every batch after the first touch of each adapter is a cache hit
    assert_eq!(cache.hits + cache.misses, m.batches);
    coord.shutdown();
    join.join().unwrap();
}

#[test]
fn quantized_and_fp16_agree_often() {
    // The serving-path outputs of FP16 vs 2@0.9 should agree on a majority
    // of prompts (the paper's "comparable performance" claim, end to end).
    let Some((coord, join)) = start() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let dir = artifacts().unwrap();
    let lora = LoraAdapter::load(dir.join(MODEL).join("modadd.lora.bin")).unwrap();
    let fp_id = coord.register_adapter(StoredAdapter::Fp16(lora), "modadd").unwrap();
    let q_id = coord.register_adapter(quantized_adapter(dir, "modadd"), "modadd").unwrap();
    let mut agree = 0;
    let n = 20;
    for i in 0..n {
        let d1 = 5 + (i % 10) as i32;
        let d2 = 5 + ((i * 3) % 10) as i32;
        let prompt = vec![1, d1, 4, d2, 3];
        let r_fp = coord
            .generate(GenRequest { adapter: fp_id, prompt: clone_vec(&prompt), max_new: 2 })
            .unwrap();
        let r_q = coord
            .generate(GenRequest { adapter: q_id, prompt, max_new: 2 })
            .unwrap();
        if r_fp.tokens == r_q.tokens {
            agree += 1;
        }
    }
    // modadd FP16 EM is ~35% and 2@0.9 drops it further, so full-answer
    // agreement is inherently noisy — require a solid plurality, not a
    // majority.
    assert!(agree * 4 >= n, "quantized path diverges too much: {agree}/{n}");
    coord.shutdown();
    join.join().unwrap();
}

fn clone_vec(v: &[i32]) -> Vec<i32> {
    v.to_vec()
}
