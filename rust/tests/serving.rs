//! Coordinator serving tests.
//!
//! Two tiers:
//! * against real `make artifacts` output (skipped with a notice when
//!   missing) — exercises trained adapters end to end;
//! * against a synthetic model via the reference engine (always run
//!   without the `pjrt` feature) — exercises the executor pool, the
//!   off-hot-path merge pipeline, prefetch, and adapter affinity
//!   hermetically.

use loraquant::adapter::LoraAdapter;
use loraquant::coordinator::{Coordinator, CoordinatorConfig, GenRequest, StoredAdapter};
use loraquant::loraquant::{quantize_site, LoraQuantConfig, QuantizedLora};
use std::path::Path;
use std::time::Duration;

const MODEL: &str = "tiny-llama-s";

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    (p.join(MODEL).join("base.bin").exists()
        && p.join(format!("{MODEL}.fwd.b8.hlo.txt")).exists()
        && p.join(format!("{MODEL}.fwd.b1.hlo.txt")).exists())
    .then_some(p)
}

fn start() -> Option<(Coordinator, std::thread::JoinHandle<()>)> {
    let dir = artifacts()?;
    let mut cfg = CoordinatorConfig::new(dir, MODEL);
    cfg.max_wait = Duration::from_millis(2);
    Some(Coordinator::start(cfg).expect("coordinator start"))
}

fn quantized_adapter(dir: &Path, task: &str) -> StoredAdapter {
    let lora = LoraAdapter::load(dir.join(MODEL).join(format!("{task}.lora.bin"))).unwrap();
    let mut q = QuantizedLora::default();
    for (site, (a, b)) in &lora.sites {
        q.sites.insert(site.clone(), quantize_site(b, a, &LoraQuantConfig::variant(2, 0.9)).unwrap());
    }
    StoredAdapter::Quantized(q)
}

#[test]
fn serves_requests_and_reports_metrics() {
    let Some((coord, join)) = start() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let dir = artifacts().unwrap();
    let id = coord.register_adapter(quantized_adapter(dir, "modadd"), "modadd").unwrap();
    // BOS d5 MARK d7 SEP — ask for 2 answer tokens
    let resp = coord
        .generate(GenRequest::new(id, vec![1, 10, 4, 12, 3], 2))
        .unwrap();
    assert_eq!(resp.tokens.len(), 2);
    assert!(resp.tokens.iter().all(|&t| (0..64).contains(&t)));
    let (m, cache, nreg) = coord.metrics().unwrap();
    assert_eq!(m.requests, 1);
    assert_eq!(nreg, 1);
    assert_eq!(cache.misses, 1, "first request must be a cache miss");
    coord.shutdown();
    join.join().unwrap();
}

#[test]
fn unknown_adapter_is_rejected() {
    let Some((coord, join)) = start() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let err = coord
        .generate(GenRequest::new(999, vec![1, 3], 1))
        .unwrap_err();
    assert!(err.to_string().contains("unknown adapter"));
    coord.shutdown();
    join.join().unwrap();
}

#[test]
fn batching_groups_by_adapter_and_caches_weights() {
    let Some((coord, join)) = start() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let dir = artifacts().unwrap();
    let id0 = coord.register_adapter(quantized_adapter(dir, "modadd"), "modadd").unwrap();
    let id1 = coord.register_adapter(quantized_adapter(dir, "transform"), "transform").unwrap();
    let mut rxs = Vec::new();
    for i in 0..16 {
        let adapter = if i % 2 == 0 { id0 } else { id1 };
        rxs.push(coord.generate_async(GenRequest::new(adapter, vec![1, 10, 4, 12, 3], 2)));
    }
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let (m, cache, _) = coord.metrics().unwrap();
    assert_eq!(m.requests, 16);
    assert!(m.batches < 16, "requests must be batched ({} batches)", m.batches);
    assert_eq!(cache.misses, 2, "one merge per adapter");
    // every batch performs exactly one counted lookup, parked or not
    assert_eq!(cache.hits + cache.misses, m.batches);
    coord.shutdown();
    join.join().unwrap();
}

#[test]
fn quantized_and_fp16_agree_often() {
    // The serving-path outputs of FP16 vs 2@0.9 should agree on a majority
    // of prompts (the paper's "comparable performance" claim, end to end).
    let Some((coord, join)) = start() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let dir = artifacts().unwrap();
    let lora = LoraAdapter::load(dir.join(MODEL).join("modadd.lora.bin")).unwrap();
    let fp_id = coord.register_adapter(StoredAdapter::Fp16(lora), "modadd").unwrap();
    let q_id = coord.register_adapter(quantized_adapter(dir, "modadd"), "modadd").unwrap();
    let mut agree = 0;
    let n = 20;
    for i in 0..n {
        let d1 = 5 + (i % 10) as i32;
        let d2 = 5 + ((i * 3) % 10) as i32;
        let prompt = vec![1, d1, 4, d2, 3];
        let r_fp = coord
            .generate(GenRequest::new(fp_id, prompt.clone(), 2))
            .unwrap();
        let r_q = coord
            .generate(GenRequest::new(q_id, prompt, 2))
            .unwrap();
        if r_fp.tokens == r_q.tokens {
            agree += 1;
        }
    }
    // modadd FP16 EM is ~35% and 2@0.9 drops it further, so full-answer
    // agreement is inherently noisy — require a solid plurality, not a
    // majority.
    assert!(agree * 4 >= n, "quantized path diverges too much: {agree}/{n}");
    coord.shutdown();
    join.join().unwrap();
}

/// Hermetic pool tests on a synthetic model (reference engine only — with
/// `pjrt` the stub artifact markers are not parseable HLO).
#[cfg(not(feature = "pjrt"))]
mod pool_tests {
    use super::*;
    use loraquant::coordinator::{MergeHook, MergeStrategy};
    use loraquant::model::ModelConfig;
    use loraquant::testutil::{synth_model_config, synth_quantized_adapter, write_synth_model};
    use std::collections::HashMap;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::mpsc;
    use std::sync::{Arc, Mutex};

    const SYNTH: &str = "synth";

    fn synth_dir(tag: &str) -> (PathBuf, ModelConfig) {
        let dir = std::env::temp_dir().join(format!("lq_serving_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = synth_model_config();
        write_synth_model(&dir, SYNTH, &cfg, &[1, 4], 42).unwrap();
        (dir, cfg)
    }

    fn pool_config(dir: &Path, workers: usize) -> CoordinatorConfig {
        let mut cfg = CoordinatorConfig::new(dir, SYNTH)
            .with_workers(workers)
            .with_buckets(vec![1, 4]);
        cfg.max_wait = Duration::from_millis(2);
        cfg
    }

    fn req(adapter: u32) -> GenRequest {
        GenRequest::new(adapter, vec![1, 5, 4, 7, 3], 2)
    }

    /// Acceptance: under `--merge-strategy factor` a mixed-adapter batch
    /// completes with **zero merge-queue entries** — no merge job ever
    /// starts, the merged-weight cache never counts a lookup, and the
    /// requests (4 tenants) decode together in fewer heterogeneous
    /// batches than requests.
    #[test]
    fn factor_strategy_serves_mixed_batch_with_zero_merge_queue_entries() {
        let (dir, mcfg) = synth_dir("factor");
        let merges = Arc::new(AtomicUsize::new(0));
        let counted = Arc::clone(&merges);
        let mut cfg = pool_config(&dir, 1).with_merge_strategy(MergeStrategy::Factor);
        // generous deadline: the batch must release on bucket-full (4),
        // proving the heterogeneous requests share one forward
        cfg.max_wait = Duration::from_millis(500);
        cfg.merge_hook = Some(MergeHook::new(move |_| {
            counted.fetch_add(1, Ordering::SeqCst);
        }));
        let (coord, join) = Coordinator::start(cfg).unwrap();
        let mut ids = Vec::new();
        for s in 0..4u64 {
            ids.push(
                coord
                    .register_adapter(synth_quantized_adapter(&mcfg, 200 + s), format!("t{s}"))
                    .unwrap(),
            );
        }
        let rxs: Vec<_> = ids.iter().map(|&id| coord.generate_async(req(id))).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert!(resp.tokens.len() <= 2, "budget respected");
        }
        let (m, cache, _) = coord.metrics().unwrap();
        assert_eq!(m.requests, 4);
        assert_eq!(merges.load(Ordering::SeqCst), 0, "factor path must never merge");
        assert_eq!((cache.hits, cache.misses), (0, 0), "merged-weight cache untouched");
        assert_eq!(m.factor_batches, m.batches, "every batch decoded factor-form");
        assert!(
            m.batches < m.requests,
            "4 tenants must share heterogeneous batches ({} batches)",
            m.batches
        );
        // prefetch is a no-op success in factor mode (nothing to warm)...
        coord.prefetch(ids[0]).recv().unwrap().unwrap();
        // ...but still validates the adapter id
        let err = coord.prefetch(999).recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("unknown adapter"));
        assert_eq!(merges.load(Ordering::SeqCst), 0, "prefetch must not merge either");
        coord.shutdown();
        join.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The factor path and the merged path compute the same function up
    /// to f32 re-association (ΔW folded into W vs applied on the
    /// activations), so greedy decodes must agree token-for-token on
    /// essentially every prompt; one divergence is tolerated in case a
    /// prompt hits an argmax near-tie inside that rounding margin.
    #[test]
    fn factor_and_merged_strategies_agree() {
        let (dir, mcfg) = synth_dir("factoreq");
        let prompts: Vec<Vec<i32>> =
            (0..6).map(|i| vec![1, 5 + i, 4, 7, 3]).collect();
        let mut outputs: Vec<Vec<Vec<i32>>> = Vec::new();
        for strategy in [MergeStrategy::Merged, MergeStrategy::Factor] {
            let cfg = pool_config(&dir, 1).with_merge_strategy(strategy);
            let (coord, join) = Coordinator::start(cfg).unwrap();
            let id = coord.register_adapter(synth_quantized_adapter(&mcfg, 77), "t").unwrap();
            let mut outs = Vec::new();
            for p in &prompts {
                let resp = coord
                    .generate(GenRequest::new(id, p.clone(), 4))
                    .unwrap();
                outs.push(resp.tokens);
            }
            outputs.push(outs);
            coord.shutdown();
            join.join().unwrap();
        }
        let agree = outputs[0].iter().zip(&outputs[1]).filter(|(a, b)| a == b).count();
        assert!(
            agree + 1 >= prompts.len(),
            "merged vs factor decode divergence: {agree}/{} prompts agree ({:?} vs {:?})",
            prompts.len(),
            outputs[0],
            outputs[1]
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Auto: a cold adapter is served factor-form immediately — its first
    /// response arrives while the background merge is still gated — and
    /// once the merge lands, later batches take the merged path.
    #[test]
    fn auto_strategy_removes_cold_merge_cliff() {
        let (dir, mcfg) = synth_dir("auto");
        let (entered_tx, entered_rx) = mpsc::channel::<()>();
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let gate_rx = Mutex::new(gate_rx);
        let merges = Arc::new(AtomicUsize::new(0));
        let counted = Arc::clone(&merges);
        let mut cfg = pool_config(&dir, 1).with_merge_strategy(MergeStrategy::Auto);
        cfg.merge_hook = Some(MergeHook::new(move |_| {
            counted.fetch_add(1, Ordering::SeqCst);
            let _ = entered_tx.send(());
            let _ = gate_rx.lock().unwrap().recv_timeout(Duration::from_secs(10));
        }));
        let (coord, join) = Coordinator::start(cfg).unwrap();
        let id = coord.register_adapter(synth_quantized_adapter(&mcfg, 91), "t").unwrap();
        let rx_cold = coord.generate_async(req(id));
        // wait until the background merge is definitely gated...
        entered_rx.recv_timeout(Duration::from_secs(5)).expect("background merge starts");
        // ...then the cold request must still be answered (factor-form)
        let resp = rx_cold
            .recv_timeout(Duration::from_secs(5))
            .expect("cold adapter must be served factor-form, not parked behind its merge")
            .unwrap();
        assert!(resp.tokens.len() <= 2);
        assert_eq!(merges.load(Ordering::SeqCst), 1, "background merge was kicked off");
        gate_tx.send(()).unwrap();
        // wait for the merged weights to land in the cache
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            let snaps = coord.metrics_per_worker().unwrap();
            if snaps.iter().any(|s| s.cached_adapters == 1) {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "merge never landed");
            std::thread::sleep(Duration::from_millis(5));
        }
        coord.generate(req(id)).unwrap();
        let (m, cache, _) = coord.metrics().unwrap();
        assert_eq!(m.requests, 2);
        assert_eq!(m.factor_batches, 1, "only the cold batch ran factor-form");
        assert!(cache.hits >= 1, "warm batch must hit the merged cache");
        assert_eq!(cache.hits + cache.misses, m.batches);
        assert_eq!(merges.load(Ordering::SeqCst), 1, "exactly one merge per adapter");
        coord.shutdown();
        join.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// FP16 adapters ride the factor path too (dense factors, same code).
    #[test]
    fn factor_strategy_serves_fp16_adapters() {
        let (dir, mcfg) = synth_dir("factorfp");
        let cfg = pool_config(&dir, 1).with_merge_strategy(MergeStrategy::Factor);
        let (coord, join) = Coordinator::start(cfg).unwrap();
        // a dense FP adapter covering one site, built from the synth shapes
        let mut rng = loraquant::testutil::Rng::new(7);
        let mut fp = loraquant::adapter::LoraAdapter::default();
        let (n_in, m_out) = mcfg.site_shape("wq").unwrap();
        let (b, a) = rng.lora_pair(m_out, n_in, mcfg.lora_rank, 0.7);
        fp.sites.insert("l0.wq".into(), (a, b));
        let id = coord.register_adapter(StoredAdapter::Fp16(fp), "fp").unwrap();
        let resp = coord.generate(req(id)).unwrap();
        assert!(resp.tokens.len() <= 2);
        let (m, _, _) = coord.metrics().unwrap();
        assert_eq!((m.requests, m.factor_batches), (1, 1));
        coord.shutdown();
        join.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pool_serves_a_mixed_workload_end_to_end() {
        let (dir, mcfg) = synth_dir("e2e");
        let (coord, join) = Coordinator::start(pool_config(&dir, 4)).unwrap();
        let mut ids = Vec::new();
        for s in 0..6u64 {
            ids.push(
                coord
                    .register_adapter(synth_quantized_adapter(&mcfg, 100 + s), format!("t{s}"))
                    .unwrap(),
            );
        }
        let mut rxs = Vec::new();
        for i in 0..24usize {
            rxs.push(coord.generate_async(req(ids[i % ids.len()])));
        }
        for rx in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert!(resp.tokens.len() <= 2, "budget respected");
        }
        let (m, cache, nreg) = coord.metrics().unwrap();
        assert_eq!(m.requests, 24);
        assert_eq!(nreg, 6);
        assert_eq!(cache.misses, 6, "one merge per adapter");
        assert_eq!(cache.hits + cache.misses, m.batches);
        coord.shutdown();
        join.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn adapter_affinity_pins_cache_to_one_worker() {
        let (dir, mcfg) = synth_dir("affinity");
        let (coord, join) = Coordinator::start(pool_config(&dir, 4)).unwrap();
        let id = coord.register_adapter(synth_quantized_adapter(&mcfg, 7), "t").unwrap();
        for _ in 0..12 {
            coord.generate(req(id)).unwrap();
        }
        let snaps = coord.metrics_per_worker().unwrap();
        let serving: Vec<_> = snaps.iter().filter(|s| s.metrics.requests > 0).collect();
        assert_eq!(serving.len(), 1, "one adapter must be owned by exactly one worker");
        assert_eq!(serving[0].metrics.requests, 12);
        assert_eq!(serving[0].cached_adapters, 1);
        coord.shutdown();
        join.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Acceptance: two adapters' cache misses merge in parallel. Both
    /// merge functions announce entry then block on their own gate; the
    /// second entry can only arrive while the first merge is still
    /// blocked, i.e. the merges overlap. (A serialized pipeline fails the
    /// second recv_timeout — no deadlock.)
    #[test]
    fn cache_misses_merge_in_parallel() {
        let (dir, mcfg) = synth_dir("parallel");
        let (entered_tx, entered_rx) = mpsc::channel::<u32>();
        let (g0_tx, g0_rx) = mpsc::channel::<()>();
        let (g1_tx, g1_rx) = mpsc::channel::<()>();
        let gates: Mutex<HashMap<u32, mpsc::Receiver<()>>> =
            Mutex::new([(0u32, g0_rx), (1u32, g1_rx)].into_iter().collect());
        let mut cfg = pool_config(&dir, 1); // same worker: parking must not serialize
        cfg.merge_workers = 2;
        cfg.merge_hook = Some(MergeHook::new(move |id| {
            let _ = entered_tx.send(id);
            let gate = gates.lock().unwrap().remove(&id);
            if let Some(g) = gate {
                let _ = g.recv_timeout(Duration::from_secs(10));
            }
        }));
        let (coord, join) = Coordinator::start(cfg).unwrap();
        let id0 = coord.register_adapter(synth_quantized_adapter(&mcfg, 1), "a").unwrap();
        let id1 = coord.register_adapter(synth_quantized_adapter(&mcfg, 2), "b").unwrap();
        assert_eq!((id0, id1), (0, 1));
        let rx_a = coord.generate_async(req(id0));
        let rx_b = coord.generate_async(req(id1));
        let first = entered_rx.recv_timeout(Duration::from_secs(5)).expect("first merge starts");
        let second = entered_rx
            .recv_timeout(Duration::from_secs(5))
            .expect("second adapter's merge must start while the first is still in flight");
        assert_ne!(first, second);
        g0_tx.send(()).unwrap();
        g1_tx.send(()).unwrap();
        rx_a.recv().unwrap().unwrap();
        rx_b.recv().unwrap().unwrap();
        coord.shutdown();
        join.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Acceptance: a request for a warm/fast adapter is not blocked behind
    /// another adapter's in-flight merge on the same worker.
    #[test]
    fn second_adapter_not_blocked_behind_first_merge() {
        let (dir, mcfg) = synth_dir("noblock");
        let (entered_tx, entered_rx) = mpsc::channel::<u32>();
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let gate_rx = Mutex::new(gate_rx);
        let slow: u32 = 0;
        let mut cfg = pool_config(&dir, 1);
        cfg.merge_workers = 2;
        cfg.merge_hook = Some(MergeHook::new(move |id| {
            let _ = entered_tx.send(id);
            if id == slow {
                let _ = gate_rx.lock().unwrap().recv_timeout(Duration::from_secs(10));
            }
        }));
        let (coord, join) = Coordinator::start(cfg).unwrap();
        let id0 = coord.register_adapter(synth_quantized_adapter(&mcfg, 3), "slow").unwrap();
        let id1 = coord.register_adapter(synth_quantized_adapter(&mcfg, 4), "fast").unwrap();
        assert_eq!(id0, slow);
        let rx_slow = coord.generate_async(req(id0));
        // wait until the slow merge is definitely holding a merge thread
        loop {
            let entered = entered_rx.recv_timeout(Duration::from_secs(5)).unwrap();
            if entered == slow {
                break;
            }
        }
        let rx_fast = coord.generate_async(req(id1));
        let fast = rx_fast
            .recv_timeout(Duration::from_secs(5))
            .expect("fast adapter served while slow merge is parked")
            .unwrap();
        assert!(fast.tokens.len() <= 2, "budget respected");
        assert!(
            matches!(rx_slow.try_recv(), Err(mpsc::TryRecvError::Empty)),
            "slow adapter must still be parked behind its gated merge"
        );
        gate_tx.send(()).unwrap();
        rx_slow.recv().unwrap().unwrap();
        coord.shutdown();
        join.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prefetch_warms_the_cache_ahead_of_traffic() {
        let (dir, mcfg) = synth_dir("prefetch");
        let (coord, join) = Coordinator::start(pool_config(&dir, 2)).unwrap();
        let id = coord.register_adapter(synth_quantized_adapter(&mcfg, 9), "t").unwrap();
        coord.prefetch(id).recv().unwrap().unwrap();
        coord.generate(req(id)).unwrap();
        let (_, cache, _) = coord.metrics().unwrap();
        assert_eq!(cache.misses, 0, "prefetched adapter must not miss");
        assert!(cache.hits >= 1);
        // prefetching an unknown adapter reports the error
        let err = coord.prefetch(999).recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("unknown adapter"));
        coord.shutdown();
        join.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn degenerate_prompts_are_rejected_without_killing_the_worker() {
        let (dir, mcfg) = synth_dir("degenerate");
        let (coord, join) = Coordinator::start(pool_config(&dir, 1)).unwrap();
        let id = coord.register_adapter(synth_quantized_adapter(&mcfg, 21), "t").unwrap();
        let err = coord
            .generate(GenRequest::new(id, vec![], 1))
            .unwrap_err();
        assert!(err.to_string().contains("empty prompt"));
        let long = vec![1i32; mcfg.seq_len + 4];
        let err = coord
            .generate(GenRequest::new(id, long, 1))
            .unwrap_err();
        assert!(err.to_string().contains("no room to generate"));
        // the worker must still be alive and serving
        coord.generate(req(id)).unwrap();
        coord.shutdown();
        join.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn remove_adapter_invalidates_and_rejects() {
        let (dir, mcfg) = synth_dir("remove");
        let (coord, join) = Coordinator::start(pool_config(&dir, 2)).unwrap();
        let id = coord.register_adapter(synth_quantized_adapter(&mcfg, 11), "t").unwrap();
        coord.generate(req(id)).unwrap();
        assert!(coord.remove_adapter(id).unwrap());
        assert!(!coord.remove_adapter(id).unwrap());
        let err = coord.generate(req(id)).unwrap_err();
        assert!(err.to_string().contains("unknown adapter"));
        let (_, _, nreg) = coord.metrics().unwrap();
        assert_eq!(nreg, 0);
        coord.shutdown();
        join.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn single_request_decodes_on_the_small_bucket() {
        // buckets [1, 4]: a lone request must not pay 4x padding. The
        // observable contract is correctness + metrics; bucket choice is
        // covered by pool unit tests, this pins the e2e path.
        let (dir, mcfg) = synth_dir("bucket");
        let (coord, join) = Coordinator::start(pool_config(&dir, 1)).unwrap();
        let id = coord.register_adapter(synth_quantized_adapter(&mcfg, 13), "t").unwrap();
        let resp = coord.generate(req(id)).unwrap();
        assert!(resp.tokens.len() <= 2, "budget respected");
        let (m, _, _) = coord.metrics().unwrap();
        assert_eq!((m.requests, m.batches), (1, 1));
        coord.shutdown();
        join.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
