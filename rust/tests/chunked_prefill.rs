//! Ragged-load acceptance for chunked prefill (DESIGN.md §13).
//!
//! One 4k-token prompt arrives ahead of a dozen short requests. With
//! `prefill_chunk = 0` the monolithic admission pass computes all 4096
//! prompt rows before any short request sees a logits row; with chunking
//! the long prompt streams in 128-row slices and the short requests
//! admit, decode and finish in between. The assertions run on the
//! scheduler's deterministic work clock (`FinishedRequest::
//! first_token_work`, forward rows computed before a request's first
//! token), so they are exact and platform-independent — no wall-clock
//! flakiness — and token outputs are checked bit-identical to the
//! monolithic oracle at every chunk size and thread count.
//!
//! Reference engine only: the synthetic model has no HLO artifacts for
//! the PJRT backend.
#![cfg(not(feature = "pjrt"))]

use loraquant::clock::Clock;
use loraquant::model::{merge_adapter, BaseWeights, ModelConfig};
use loraquant::runtime::{DeviceWeights, Engine};
use loraquant::scheduler::{
    run_continuous, AdmissionQueue, ContinuousConfig, LaneRequest, LoopStats, SessionStepper,
};
use loraquant::testutil::{synth_model_config, write_synth_model};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

/// Long-prompt length (the "4k prompt" of the ragged scenario).
const LONG: usize = 4096;
/// Prefill chunk size under test.
const CHUNK: usize = 128;
/// Short requests queued behind the long prompt.
const SHORTS: usize = 12;

/// A narrow synthetic model: attention cost is O(T²) and the long
/// prefill runs three times in this test, so the width stays minimal
/// while `seq_len` holds the 4k prompt plus decode room.
fn fixture(tag: &str) -> (PathBuf, ModelConfig, Engine, DeviceWeights) {
    let dir = std::env::temp_dir().join(format!("lq_ragged_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = synth_model_config();
    cfg.d_model = 16;
    cfg.n_heads = 2;
    cfg.d_ff = 32;
    cfg.vocab = 32;
    cfg.seq_len = LONG + 32;
    write_synth_model(&dir, "synth", &cfg, &[4], 4242).unwrap();
    let base = BaseWeights::load(dir.join("synth")).unwrap();
    let mut engine = Engine::new(&dir).unwrap();
    engine.load_model_fwd("synth", 4, base.cfg.param_names().len()).unwrap();
    let w = engine.upload_weights(&merge_adapter(&base, &BTreeMap::new()).unwrap()).unwrap();
    (dir, cfg, engine, w)
}

/// Deterministic ragged workload: one 4k prompt (tenant 0) queued first,
/// then `SHORTS` short prompts on distinct tenants.
fn ragged_queue(cfg: &ModelConfig) -> AdmissionQueue {
    let mut queue = AdmissionQueue::new();
    let span = (cfg.vocab - 2) as i32; // keep clear of PAD/EOS
    let long: Vec<i32> = (0..LONG).map(|i| 1 + (i as i32 * 7 + 3) % span).collect();
    queue.push(LaneRequest {
        id: 0,
        tenant: 0,
        prompt: long,
        budget: 3,
        adapter: None,
        enqueued: Instant::now(),
    });
    for s in 0..SHORTS {
        let prompt: Vec<i32> =
            (0..3 + s % 4).map(|i| 1 + (i as i32 * 5 + s as i32) % span).collect();
        queue.push(LaneRequest {
            id: 1 + s as u64,
            tenant: 1 + s as u32,
            prompt,
            budget: 2,
            adapter: None,
            enqueued: Instant::now(),
        });
    }
    queue
}

/// One run at a given chunk size: per-request `(tokens,
/// first_token_work)` plus the loop stats.
fn run_ragged(
    engine: &Engine,
    cfg: &ModelConfig,
    w: &DeviceWeights,
    chunk: usize,
) -> (Vec<(Vec<i32>, u64)>, LoopStats) {
    let clock = Clock::real();
    let mut queue = ragged_queue(cfg);
    let mut slot = None;
    let mut stepper = SessionStepper::new(engine, "synth/b4", w, &mut slot);
    let ccfg = ContinuousConfig {
        lanes: 2,
        seq_len: cfg.seq_len,
        vocab: cfg.vocab,
        prefill_chunk: chunk,
    };
    let mut got = vec![(Vec::new(), 0u64); 1 + SHORTS];
    let stats = run_continuous(&mut stepper, &ccfg, &mut queue, &clock, |fin| {
        got[fin.id as usize] = (fin.tokens, fin.first_token_work);
    })
    .unwrap();
    assert_eq!(stats.finished as usize, 1 + SHORTS, "chunk={chunk}");
    (got, stats)
}

#[test]
fn short_request_ttft_stays_bounded_while_4k_prompt_prefills() {
    let (dir, cfg, mut engine, w) = fixture("ttft");
    engine.set_compute_threads(2);
    let (mono, mono_stats) = run_ragged(&engine, &cfg, &w, 0);
    let (chunked, stats) = run_ragged(&engine, &cfg, &w, CHUNK);

    // tokens are bit-identical to the monolithic oracle, long and short
    for id in 0..=SHORTS {
        assert_eq!(chunked[id].0, mono[id].0, "request {id}: tokens");
    }
    // the work clock is invariant under chunking: the same prompt rows
    // and one step row per later token get computed either way
    assert_eq!(stats.work_rows, mono_stats.work_rows);

    // monolithic: no short request produces output before the admission
    // pass that computes all 4096 long-prompt rows
    for id in 1..=SHORTS {
        assert!(mono[id].1 > LONG as u64, "request {id}: monolithic floor");
    }
    // chunked: the first short admits alone (the long prompt is mid-chunk
    // and claims no admission pass), so its first token costs only its
    // own prompt rows — and *every* short beats the monolithic path
    assert!(
        chunked[1].1 <= (CHUNK + 16) as u64,
        "first short saw first token only after {} work rows",
        chunked[1].1
    );
    for id in 1..=SHORTS {
        assert!(
            chunked[id].1 < LONG as u64 && chunked[id].1 < mono[id].1,
            "request {id}: chunked TTFT work {} must beat monolithic {}",
            chunked[id].1,
            mono[id].1
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ragged_chunked_schedule_is_thread_count_invariant() {
    let (dir, cfg, mut engine, w) = fixture("threads");
    engine.set_compute_threads(1);
    let (serial, serial_stats) = run_ragged(&engine, &cfg, &w, CHUNK);
    engine.set_compute_threads(4);
    let (threaded, threaded_stats) = run_ragged(&engine, &cfg, &w, CHUNK);
    // bit-identical tokens *and* an identical work schedule: the steal
    // order of the executor never reaches the scheduler's state
    for id in 0..=SHORTS {
        assert_eq!(threaded[id], serial[id], "request {id}");
    }
    assert_eq!(threaded_stats.work_rows, serial_stats.work_rows);
    assert_eq!(threaded_stats.decode_steps, serial_stats.decode_steps);
    assert_eq!(threaded_stats.admits, serial_stats.admits);
    let _ = std::fs::remove_dir_all(&dir);
}
