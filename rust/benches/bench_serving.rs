//! Serving benchmark (P1 in DESIGN.md §5): end-to-end multi-LoRA serving
//! through the coordinator. Every scenario is a thin driver over a
//! [`ScenarioSpec`] replayed by `scenario::run_scenario` — the exact code
//! path the deterministic test suite exercises (DESIGN.md §9).
//!
//! Scenarios:
//! 1. open-loop Zipf workload under **virtual time** — latency
//!    percentiles, batching efficacy, cache behaviour under eviction
//!    pressure, with seconds of simulated trace replayed in milliseconds;
//! 2. **multi-worker scaling** (real time) — a saturating mixed-adapter
//!    workload replayed at pool sizes 1/2/4; reports req/s and speedup
//!    vs one worker;
//! 3. cold vs prefetched first-burst latency (real time);
//! 4. **heterogeneous-adapter batches** — 16 tenants hit round-robin
//!    (adjacent requests never share an adapter) under `merged` vs
//!    `factor` vs `auto` (real time for req/s comparability).
//!
//! Scenario 1, 2 and 4 results are written to `BENCH_serving.json` — one
//! machine-readable snapshot per run (each PR's committed snapshot is one
//! point of the perf trajectory).
//!
//! Runs against real `make artifacts` output when present; otherwise (on
//! the reference engine) it synthesizes a model + adapters and runs the
//! same scenarios hermetically.

use loraquant::coordinator::MergeStrategy;
use loraquant::experiments::Settings;
use loraquant::scenario::{run_scenario, ClockMode, ScenarioEnv, ScenarioSpec};
use loraquant::workload::WorkloadConfig;
use std::time::Duration;

/// Scenario environment — real artifacts when available, synthetic
/// otherwise.
fn setup() -> anyhow::Result<Option<ScenarioEnv>> {
    let settings = Settings::from_env();
    if let Some(model) = settings.models.first().cloned() {
        return Ok(Some(ScenarioEnv::from_artifacts(settings.artifacts, model)?));
    }
    if cfg!(feature = "pjrt") {
        eprintln!("bench_serving: no artifacts — run `make artifacts`");
        return Ok(None);
    }
    eprintln!("bench_serving: no artifacts — using a synthetic model on the reference engine");
    Ok(Some(ScenarioEnv::synth("bench", 4)?))
}

/// req/s over the trace span (first submit → last completion).
fn rps(ok: usize, span: Duration) -> f64 {
    ok as f64 / span.as_secs_f64().max(1e-9)
}

fn main() -> anyhow::Result<()> {
    let Some(env) = setup()? else {
        return Ok(());
    };
    let model = env.model.clone();
    let synthetic = model == "synth";

    // The "tight" cache row must actually evict: the synthetic model's
    // merged weights are ~50 KB vs several MB for the real one, so scale
    // the budget unit down when running on synthetic adapters.
    let cache_unit: usize = if synthetic { 1 << 14 } else { 1 << 20 };
    if synthetic {
        println!("(synthetic model: cache budgets are in 16 KB units, not MB)");
    }

    // machine-readable rows accumulated across scenarios
    let mut json_rows: Vec<String> = Vec::new();

    // ---- scenario 1: open-loop Zipf, virtual time -----------------------
    println!("# Serving — Zipf multi-LoRA workload through the coordinator ({model}, virtual time)");
    for (n_adapters, cache_mb, rate) in
        [(4usize, 256usize, 100.0f64), (16, 256, 100.0), (16, 4, 100.0), (16, 256, 400.0)]
    {
        let spec = ScenarioSpec {
            name: format!("open_loop/a{n_adapters}/c{cache_mb}/r{rate}"),
            mode: ClockMode::Virtual,
            n_adapters,
            cache_budget_bytes: cache_mb * cache_unit,
            max_wait: Duration::from_millis(5),
            workload: WorkloadConfig { rate, zipf_alpha: 1.1, n_requests: 128, seed: 11 },
            max_new: 3,
            ..Default::default()
        };
        let run = run_scenario(&spec, &env)?;
        let s = &run.summary;
        println!(
            "adapters={n_adapters:<3} cache={cache_mb:>4}MB rate={rate:>5.0}/s | {}/{} ok | p50={:?} p95={:?} mean_batch={:.2} | hit_rate={:.2} evictions={} | wall {:?}",
            s.ok,
            s.requests,
            s.latency.quantile(0.5),
            s.latency.quantile(0.95),
            s.mean_batch,
            s.cache.hit_rate(),
            s.cache.evictions,
            s.real_wall,
        );
        json_rows.push(format!(
            r#"{{"scenario":"open_loop_virtual","adapters":{n_adapters},"cache_units":{cache_mb},"rate":{rate},"requests":{},"ok":{},"p50_us":{},"p95_us":{},"mean_batch":{:.2},"evictions":{},"wall_ms":{}}}"#,
            s.requests,
            s.ok,
            s.latency.quantile(0.5).as_micros(),
            s.latency.quantile(0.95).as_micros(),
            s.mean_batch,
            s.cache.evictions,
            s.real_wall.as_millis(),
        ));
    }

    // ---- scenario 2: multi-worker scaling on a saturating mixed load ----
    println!("\n# Multi-worker scaling — 16 tenants, 192 closed-loop requests");
    let mut base_rps = None;
    for workers in [1usize, 2, 4] {
        let spec = ScenarioSpec {
            name: format!("worker_scaling/w{workers}"),
            mode: ClockMode::RealTime,
            workers,
            merge_workers: 2,
            n_adapters: 16,
            max_wait: Duration::from_millis(2),
            // rate only shapes (near-zero) arrival gaps: effectively
            // closed-loop submission, peak-throughput measurement
            workload: WorkloadConfig { rate: 1e9, zipf_alpha: 0.6, n_requests: 192, seed: 23 },
            max_new: 3,
            ..Default::default()
        };
        let run = run_scenario(&spec, &env)?;
        let s = &run.summary;
        let r = rps(s.ok, s.trace_span);
        let speedup = base_rps.map_or(1.0, |b: f64| r / b);
        if base_rps.is_none() {
            base_rps = Some(r);
        }
        println!(
            "workers={workers} | {}/{} ok in {:?} | {r:7.1} req/s | {speedup:.2}x vs 1 worker | mean_batch={:.2} hit_rate={:.2}",
            s.ok,
            s.requests,
            s.trace_span,
            s.mean_batch,
            s.cache.hit_rate(),
        );
        json_rows.push(format!(
            r#"{{"scenario":"worker_scaling","workers":{workers},"requests":{},"ok":{},"req_per_s":{r:.1},"speedup":{speedup:.2},"mean_batch":{:.2}}}"#,
            s.requests,
            s.ok,
            s.mean_batch,
        ));
    }

    // ---- scenario 3: cold start vs prefetch -----------------------------
    println!("\n# Prefetch — time to first response over 8 cold tenants");
    for prefetch in [false, true] {
        let spec = ScenarioSpec {
            name: format!("prefetch/{prefetch}"),
            mode: ClockMode::RealTime,
            workers: 2,
            merge_workers: 2,
            n_adapters: 8,
            max_wait: Duration::from_millis(2),
            workload: WorkloadConfig { rate: 1e9, zipf_alpha: 0.0, n_requests: 8, seed: 5 },
            round_robin: true, // every tenant exactly once
            max_new: 2,
            prefetch,
            ..Default::default()
        };
        let run = run_scenario(&spec, &env)?;
        let s = &run.summary;
        println!(
            "prefetch={prefetch:<5} | burst served in {:?} | p95={:?} | misses_on_path={}",
            s.trace_span,
            s.latency.quantile(0.95),
            s.cache.misses,
        );
    }

    // ---- scenario 4: heterogeneous-adapter batches, merged vs factor ----
    println!("\n# Merge strategy — 16 tenants round-robin, 128 closed-loop requests");
    for strategy in [MergeStrategy::Merged, MergeStrategy::Factor, MergeStrategy::Auto] {
        if cfg!(feature = "pjrt") && strategy != MergeStrategy::Merged {
            println!("strategy={strategy:<6} | skipped (PJRT backend is merged-only)");
            continue;
        }
        let spec = ScenarioSpec {
            name: format!("hetero_batch/{strategy}"),
            mode: ClockMode::RealTime,
            strategy,
            merge_workers: 2,
            n_adapters: 16,
            max_wait: Duration::from_millis(2),
            workload: WorkloadConfig { rate: 1e9, zipf_alpha: 0.0, n_requests: 128, seed: 31 },
            // round-robin: adjacent requests never share an adapter, so
            // the merged path cannot amortize a batch across tenants
            // while the factor path fills heterogeneous buckets
            round_robin: true,
            max_new: 3,
            ..Default::default()
        };
        let run = run_scenario(&spec, &env)?;
        let s = &run.summary;
        let r = rps(s.ok, s.trace_span);
        let p95_us = s.latency.quantile(0.95).as_micros() as u64;
        println!(
            "strategy={strategy:<6} | {}/{} ok | {r:7.1} req/s | p95={p95_us}µs | mean_batch={:.2} factor_batches={} merges(misses)={}",
            s.ok,
            s.requests,
            s.mean_batch,
            s.factor_batches,
            s.cache.misses,
        );
        json_rows.push(format!(
            r#"{{"scenario":"hetero_batch","strategy":"{strategy}","adapters":16,"requests":{},"ok":{},"req_per_s":{r:.1},"p95_us":{p95_us},"mean_batch":{:.2},"batches":{},"factor_batches":{},"cache_misses":{}}}"#,
            s.requests,
            s.ok,
            s.mean_batch,
            s.batches,
            s.factor_batches,
            s.cache.misses,
        ));
    }

    let json = format!(
        "{{\"bench\":\"serving\",\"model\":\"{model}\",\"synthetic\":{synthetic},\"scenarios\":[{}]}}\n",
        json_rows.join(",")
    );
    std::fs::write("BENCH_serving.json", &json)?;
    println!("\nwrote BENCH_serving.json ({} scenario rows)", json_rows.len());
    Ok(())
}
