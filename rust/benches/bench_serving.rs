//! Serving benchmark (P1 in DESIGN.md §5): end-to-end multi-LoRA serving
//! through the coordinator.
//!
//! Scenarios:
//! 1. open-loop Zipf workload — latency percentiles, batching efficacy,
//!    cache behaviour under eviction pressure;
//! 2. **multi-worker scaling** — a saturating mixed-adapter workload
//!    replayed at pool sizes 1/2/4; reports req/s and speedup vs one
//!    worker (the off-hot-path merge pipeline + per-worker engines should
//!    give ≥ 1.5× at 4 workers);
//! 3. cold vs prefetched first-burst latency;
//! 4. **heterogeneous-adapter batches** — 16 tenants hit round-robin
//!    (adjacent requests never share an adapter: the worst case for
//!    per-adapter batching, the best case for factor-form mixed batches)
//!    under `merged` vs `factor` vs `auto`.
//!
//! Scenario 2 and 4 results are also written to `BENCH_serving.json` —
//! one machine-readable snapshot per run (each PR's committed snapshot
//! is one point of the perf trajectory).
//!
//! Runs against real `make artifacts` output when present; otherwise (on
//! the reference engine) it synthesizes a model + adapters and runs the
//! same scenarios hermetically.

use loraquant::adapter::LoraAdapter;
use loraquant::coordinator::{
    Coordinator, CoordinatorConfig, GenRequest, MergeStrategy, StoredAdapter,
};
use loraquant::experiments::{lq, Settings};
use loraquant::loraquant::{quantize_site, QuantizedLora};
use loraquant::testutil::{synth_model_config, synth_quantized_adapter, write_synth_model};
use loraquant::workload::{generate, zipf_ids, WorkloadConfig};
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// (artifacts dir, model name, pre-built adapters) — real when available,
/// synthetic otherwise.
fn setup() -> anyhow::Result<Option<(PathBuf, String, Vec<(String, StoredAdapter)>)>> {
    let settings = Settings::from_env();
    if let Some(model) = settings.models.first().cloned() {
        let tasks = ["modadd", "modchain", "transform", "keyword"];
        let qcfg = lq(2, 0.9);
        let mut adapters = Vec::new();
        for task in tasks {
            let lora =
                LoraAdapter::load(settings.artifacts.join(&model).join(format!("{task}.lora.bin")))?;
            let mut q = QuantizedLora::default();
            for (site, (a, b)) in &lora.sites {
                q.sites.insert(site.clone(), quantize_site(b, a, &qcfg));
            }
            adapters.push((task.to_string(), StoredAdapter::Quantized(q)));
        }
        return Ok(Some((settings.artifacts, model, adapters)));
    }
    if cfg!(feature = "pjrt") {
        eprintln!("bench_serving: no artifacts — run `make artifacts`");
        return Ok(None);
    }
    // reference engine: synthesize a model + adapters
    let dir = std::env::temp_dir().join(format!("lq_bench_serving_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mcfg = synth_model_config();
    write_synth_model(&dir, "synth", &mcfg, &[1, 8], 17)?;
    let adapters = (0..4)
        .map(|i| (format!("task{i}"), synth_quantized_adapter(&mcfg, 100 + i)))
        .collect();
    eprintln!("bench_serving: no artifacts — using a synthetic model on the reference engine");
    Ok(Some((dir, "synth".to_string(), adapters)))
}

fn main() -> anyhow::Result<()> {
    let Some((artifacts, model, adapters)) = setup()? else {
        return Ok(());
    };

    // The "tight" cache row must actually evict: the synthetic model's
    // merged weights are ~50 KB vs several MB for the real one, so scale
    // the budget unit down when running on synthetic adapters.
    let synthetic = model == "synth";
    let cache_unit: usize = if synthetic { 1 << 14 } else { 1 << 20 };
    if synthetic {
        println!("(synthetic model: cache budgets are in 16 KB units, not MB)");
    }

    println!("# Serving — Zipf multi-LoRA workload through the coordinator ({model})");
    for (n_adapters, cache_mb, rate) in
        [(4usize, 256usize, 100.0f64), (16, 256, 100.0), (16, 4, 100.0), (16, 256, 400.0)]
    {
        let mut cfg = CoordinatorConfig::new(&artifacts, &model);
        cfg.cache_budget_bytes = cache_mb * cache_unit;
        cfg.max_wait = Duration::from_millis(5);
        let (coord, join) = Coordinator::start(cfg)?;
        let mut ids = Vec::new();
        for i in 0..n_adapters {
            let (task, q) = &adapters[i % adapters.len()];
            ids.push(coord.register_adapter(q.clone(), task.clone())?);
        }
        let wl = WorkloadConfig { rate, n_requests: 128, zipf_alpha: 1.1, seed: 11 };
        let schedule = generate(&wl, &ids);
        let start = Instant::now();
        let mut rxs = Vec::new();
        for arr in &schedule {
            let el = start.elapsed();
            if arr.at > el {
                std::thread::sleep(arr.at - el);
            }
            rxs.push(coord.generate_async(GenRequest {
                adapter: arr.adapter,
                prompt: vec![1, 5, 4, 7, 3],
                max_new: 3,
            }));
        }
        let ok = rxs.into_iter().filter(|rx| matches!(rx.recv(), Ok(Ok(_)))).count();
        let wall = start.elapsed();
        let (m, cache, _) = coord.metrics()?;
        println!(
            "adapters={n_adapters:<3} cache={cache_mb:>4}MB rate={rate:>5.0}/s | {ok}/128 ok, {:.1} req/s | {} | hit_rate={:.2} evictions={}",
            ok as f64 / wall.as_secs_f64(),
            m.summary(),
            cache.hit_rate(),
            cache.evictions,
        );
        coord.shutdown();
        let _ = join.join();
    }

    // machine-readable rows accumulated across scenarios
    let mut json_rows: Vec<String> = Vec::new();

    // ---- scenario 2: multi-worker scaling on a saturating mixed load ----
    println!("\n# Multi-worker scaling — 16 tenants, 192 closed-loop requests");
    // rate only shapes (discarded) arrival times here; keep it huge so the
    // closed-loop mix is effectively instantaneous
    let wl = WorkloadConfig { rate: 1e9, n_requests: 192, zipf_alpha: 0.6, seed: 23 };
    let mut base_rps = None;
    for workers in [1usize, 2, 4] {
        let mut cfg = CoordinatorConfig::new(&artifacts, &model).with_workers(workers);
        cfg.max_wait = Duration::from_millis(2);
        let (coord, join) = Coordinator::start(cfg)?;
        let mut ids = Vec::new();
        for i in 0..16 {
            let (task, q) = &adapters[i % adapters.len()];
            ids.push(coord.register_adapter(q.clone(), task.clone())?);
        }
        let mix = zipf_ids(&wl, &ids);
        let start = Instant::now();
        let rxs: Vec<_> = mix
            .iter()
            .map(|&adapter| {
                coord.generate_async(GenRequest {
                    adapter,
                    prompt: vec![1, 5, 4, 7, 3],
                    max_new: 3,
                })
            })
            .collect();
        let ok = rxs.into_iter().filter(|rx| matches!(rx.recv(), Ok(Ok(_)))).count();
        let wall = start.elapsed();
        let rps = ok as f64 / wall.as_secs_f64();
        let speedup = base_rps.map_or(1.0, |b: f64| rps / b);
        if base_rps.is_none() {
            base_rps = Some(rps);
        }
        let (m, cache, _) = coord.metrics()?;
        println!(
            "workers={workers} | {ok}/{} ok in {wall:.2?} | {rps:7.1} req/s | {:.2}x vs 1 worker | mean_batch={:.2} hit_rate={:.2}",
            mix.len(),
            speedup,
            m.mean_batch_size(),
            cache.hit_rate(),
        );
        json_rows.push(format!(
            r#"{{"scenario":"worker_scaling","workers":{workers},"requests":{},"ok":{ok},"req_per_s":{rps:.1},"speedup":{speedup:.2},"mean_batch":{:.2}}}"#,
            mix.len(),
            m.mean_batch_size(),
        ));
        coord.shutdown();
        let _ = join.join();
    }

    // ---- scenario 3: cold start vs prefetch -----------------------------
    println!("\n# Prefetch — time to first response over 8 cold tenants");
    for prefetch in [false, true] {
        let mut cfg = CoordinatorConfig::new(&artifacts, &model).with_workers(2);
        cfg.max_wait = Duration::from_millis(2);
        let (coord, join) = Coordinator::start(cfg)?;
        let mut ids = Vec::new();
        for i in 0..8 {
            let (task, q) = &adapters[i % adapters.len()];
            ids.push(coord.register_adapter(q.clone(), task.clone())?);
        }
        if prefetch {
            let waits: Vec<_> = ids.iter().map(|&id| coord.prefetch(id)).collect();
            for rx in waits {
                let _ = rx.recv();
            }
        }
        let start = Instant::now();
        let rxs: Vec<_> = ids
            .iter()
            .map(|&adapter| {
                coord.generate_async(GenRequest {
                    adapter,
                    prompt: vec![1, 5, 4, 7, 3],
                    max_new: 2,
                })
            })
            .collect();
        for rx in rxs {
            let _ = rx.recv();
        }
        let wall = start.elapsed();
        let (m, cache, _) = coord.metrics()?;
        let p95 = m.e2e_latency.as_ref().map(|h| h.quantile(0.95));
        println!(
            "prefetch={prefetch:<5} | burst served in {wall:.2?} | p95={p95:?} | misses_on_path={}",
            cache.misses,
        );
        coord.shutdown();
        let _ = join.join();
    }

    // ---- scenario 4: heterogeneous-adapter batches, merged vs factor ----
    println!("\n# Merge strategy — 16 tenants round-robin, 128 closed-loop requests");
    for strategy in [MergeStrategy::Merged, MergeStrategy::Factor, MergeStrategy::Auto] {
        if cfg!(feature = "pjrt") && strategy != MergeStrategy::Merged {
            println!("strategy={strategy:<6} | skipped (PJRT backend is merged-only)");
            continue;
        }
        let mut cfg =
            CoordinatorConfig::new(&artifacts, &model).with_merge_strategy(strategy);
        cfg.max_wait = Duration::from_millis(2);
        let (coord, join) = Coordinator::start(cfg)?;
        let mut ids = Vec::new();
        for i in 0..16 {
            let (task, q) = &adapters[i % adapters.len()];
            ids.push(coord.register_adapter(q.clone(), task.clone())?);
        }
        // round-robin: adjacent requests never share an adapter, so the
        // merged path cannot amortize a batch across tenants while the
        // factor path fills heterogeneous buckets
        let start = Instant::now();
        let rxs: Vec<_> = (0..128)
            .map(|i| {
                coord.generate_async(GenRequest {
                    adapter: ids[i % ids.len()],
                    prompt: vec![1, 5, 4, 7, 3],
                    max_new: 3,
                })
            })
            .collect();
        let ok = rxs.into_iter().filter(|rx| matches!(rx.recv(), Ok(Ok(_)))).count();
        let wall = start.elapsed();
        let rps = ok as f64 / wall.as_secs_f64();
        let (m, cache, _) = coord.metrics()?;
        let p95_us =
            m.e2e_latency.as_ref().map_or(0, |h| h.quantile(0.95).as_micros() as u64);
        println!(
            "strategy={strategy:<6} | {ok}/128 ok | {rps:7.1} req/s | p95={p95_us}µs | mean_batch={:.2} factor_batches={} merges(misses)={}",
            m.mean_batch_size(),
            m.factor_batches,
            cache.misses,
        );
        json_rows.push(format!(
            r#"{{"scenario":"hetero_batch","strategy":"{strategy}","adapters":16,"requests":128,"ok":{ok},"req_per_s":{rps:.1},"p95_us":{p95_us},"mean_batch":{:.2},"batches":{},"factor_batches":{},"cache_misses":{}}}"#,
            m.mean_batch_size(),
            m.batches,
            m.factor_batches,
            cache.misses,
        ));
        coord.shutdown();
        let _ = join.join();
    }

    let json = format!(
        "{{\"bench\":\"serving\",\"model\":\"{model}\",\"synthetic\":{synthetic},\"scenarios\":[{}]}}\n",
        json_rows.join(",")
    );
    std::fs::write("BENCH_serving.json", &json)?;
    println!("\nwrote BENCH_serving.json ({} scenario rows)", json_rows.len());
    Ok(())
}
