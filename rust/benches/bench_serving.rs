//! Serving benchmark (P1 in DESIGN.md §5): end-to-end multi-LoRA serving
//! through the coordinator — latency percentiles, throughput, batching
//! efficacy, and cache behaviour under a Zipf workload; plus the effect of
//! the merged-weight cache budget (eviction pressure).

use loraquant::adapter::LoraAdapter;
use loraquant::coordinator::{Coordinator, CoordinatorConfig, GenRequest, StoredAdapter};
use loraquant::experiments::{lq, Settings};
use loraquant::loraquant::{quantize_site, QuantizedLora};
use loraquant::workload::{generate, WorkloadConfig};
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let settings = Settings::from_env();
    let Some(model) = settings.models.first().cloned() else {
        eprintln!("bench_serving: no artifacts — run `make artifacts`");
        return Ok(());
    };

    // Pre-quantize one adapter per task; clones simulate many tenants.
    let tasks = ["modadd", "modchain", "transform", "keyword"];
    let qcfg = lq(2, 0.9);
    let mut quantized = Vec::new();
    for task in tasks {
        let lora = LoraAdapter::load(settings.artifacts.join(&model).join(format!("{task}.lora.bin")))?;
        let mut q = QuantizedLora::default();
        for (site, (a, b)) in &lora.sites {
            q.sites.insert(site.clone(), quantize_site(b, a, &qcfg));
        }
        quantized.push((task, q));
    }

    println!("# Serving — Zipf multi-LoRA workload through the coordinator ({model})");
    for (n_adapters, cache_mb, rate) in
        [(4usize, 256usize, 100.0f64), (16, 256, 100.0), (16, 4, 100.0), (16, 256, 400.0)]
    {
        let mut cfg = CoordinatorConfig::new(&settings.artifacts, &model);
        cfg.cache_budget_bytes = cache_mb << 20;
        cfg.max_wait = Duration::from_millis(5);
        let (coord, join) = Coordinator::start(cfg)?;
        let mut ids = Vec::new();
        for i in 0..n_adapters {
            let (task, q) = &quantized[i % quantized.len()];
            ids.push(coord.register_adapter(StoredAdapter::Quantized(q.clone()), *task)?);
        }
        let wl = WorkloadConfig { rate, n_requests: 128, zipf_alpha: 1.1, seed: 11 };
        let schedule = generate(&wl, &ids);
        let start = Instant::now();
        let mut rxs = Vec::new();
        for arr in &schedule {
            let el = start.elapsed();
            if arr.at > el {
                std::thread::sleep(arr.at - el);
            }
            rxs.push(coord.generate_async(GenRequest {
                adapter: arr.adapter,
                prompt: vec![1, 5, 4, 7, 3],
                max_new: 3,
            }));
        }
        let ok = rxs.into_iter().filter(|rx| matches!(rx.recv(), Ok(Ok(_)))).count();
        let wall = start.elapsed();
        let (m, cache, _) = coord.metrics()?;
        println!(
            "adapters={n_adapters:<3} cache={cache_mb:>4}MB rate={rate:>5.0}/s | {ok}/128 ok, {:.1} req/s | {} | hit_rate={:.2} evictions={}",
            ok as f64 / wall.as_secs_f64(),
            m.summary(),
            cache.hit_rate(),
            cache.evictions,
        );
        coord.shutdown();
        let _ = join.join();
    }
    Ok(())
}
