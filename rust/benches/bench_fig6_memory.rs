//! Figure 6 (App. D) reproduction: total memory when loading N adapters on
//! one base model, FP16 adapters vs LoRAQuant(2@0.8) — byte-exact from the
//! registry's accounting (no simulation needed; this is arithmetic the
//! registry already does for real adapters).

use loraquant::adapter::LoraAdapter;
use loraquant::bench::Table;
use loraquant::coordinator::{AdapterRegistry, StoredAdapter};
use loraquant::experiments::{lq, Settings};
use loraquant::loraquant::{quantize_site, QuantizedLora};
use loraquant::model::BaseWeights;

fn main() -> anyhow::Result<()> {
    let settings = Settings::from_env();
    let Some(model) = settings.models.first().cloned() else {
        eprintln!("bench_fig6_memory: no artifacts — run `make artifacts`");
        return Ok(());
    };
    let dir = settings.artifacts.join(&model);
    let base = BaseWeights::load(&dir)?;
    let lora = LoraAdapter::load(dir.join("modadd.lora.bin"))?;
    let qcfg = lq(2, 0.8);
    let mut q = QuantizedLora::default();
    for (site, (a, b)) in &lora.sites {
        q.sites.insert(site.clone(), quantize_site(b, a, &qcfg)?);
    }

    println!("# Figure 6 — memory vs number of loaded adapters (model {model})");
    println!("# base model: {} fp16 bytes; adapter fp16: {} bytes; LoRAQuant(2@0.8): {} bytes ({:.2} avg bits)",
        base.fp16_bytes(), lora.fp16_bytes(), q.packed_bytes(), q.avg_bits());
    let tbl = Table::new(&[10, 16, 16, 10]);
    println!(
        "{}",
        tbl.row(&["n_loras".into(), "fp16_total_MB".into(), "lq_total_MB".into(), "ratio".into()])
    );
    println!("{}", tbl.sep());

    for n in [0usize, 10, 25, 50, 100, 200, 400, 700, 1000] {
        // drive the real registry accounting
        let mut reg_fp = AdapterRegistry::new();
        let mut reg_q = AdapterRegistry::new();
        for _ in 0..n.min(64) {
            reg_fp.register(StoredAdapter::Fp16(lora.clone()), "t");
            reg_q.register(StoredAdapter::Quantized(q.clone()), "t");
        }
        // extrapolate linearly beyond the physically-registered sample
        let scale = if n == 0 { 0.0 } else { n as f64 / n.min(64) as f64 };
        let fp_total = base.fp16_bytes() as f64 + reg_fp.total_bytes() as f64 * scale;
        let q_total = base.fp16_bytes() as f64 + reg_q.total_bytes() as f64 * scale;
        println!(
            "{}",
            tbl.row(&[
                format!("{n}"),
                format!("{:.3}", fp_total / (1 << 20) as f64),
                format!("{:.3}", q_total / (1 << 20) as f64),
                format!("{:.2}x", fp_total / q_total),
            ])
        );
    }
    println!("# shape check: fp16 grows ~{:.1}x faster per adapter than LoRAQuant",
        lora.fp16_bytes() as f64 / q.packed_bytes() as f64);
    Ok(())
}
