//! Table 1 reproduction: 12 methods × 4 tasks × all trained models.
//! Prints the paper's grid (per-task score, Avg Perf., Avg Bit) plus
//! quantization wall-time per method.
//!
//! Paper: LLaMA2-7B/13B + Mistral-7B on GSM8K/MATH/HumanEval/XSum.
//! Here:  tiny-llama-s/m + tiny-mistral-s on modadd/modchain/transform/
//!        keyword (DESIGN.md §2 substitutions). Expected *shape*: RTN-1bit
//!        collapses; BIN degrades hard; LoRAQuant 2@ρ < 2 avg bits at
//!        quality ≈ GPTQ-2/PB-LLM/BiLLM; 3@ρ beats both near their bits.

use loraquant::bench::Table;
use loraquant::experiments::{apply_method, Method, ModelCtx, Settings};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let settings = Settings::from_env();
    if settings.models.is_empty() {
        eprintln!("bench_table1: no model artifacts found — run `make artifacts` first");
        return Ok(());
    }
    println!("# Table 1 — performance & average bitwidth ({} eval examples/cell)", settings.eval_n);
    let tbl = Table::new(&[14, 22, 9, 9, 9, 9, 10, 8, 9]);
    println!(
        "{}",
        tbl.row(&[
            "model".into(),
            "method".into(),
            "modadd".into(),
            "modchain".into(),
            "transform".into(),
            "keyword".into(),
            "avg_perf".into(),
            "avg_bit".into(),
            "quant_s".into(),
        ])
    );
    println!("{}", tbl.sep());

    for model in &settings.models {
        let ctx = ModelCtx::load(&settings, model)?;
        let cluster: Vec<&loraquant::adapter::LoraAdapter> =
            ctx.tasks.iter().map(|t| &t.lora).collect();
        for method in Method::table1_rows() {
            let mut scores = Vec::new();
            let mut bits = Vec::new();
            let mut quant_time = 0.0f64;
            for td in &ctx.tasks {
                let t0 = Instant::now();
                let (deltas, avg_bits) = apply_method(&method, td, &cluster);
                quant_time += t0.elapsed().as_secs_f64();
                let score = ctx.eval_deltas(&deltas, &td.eval)?;
                scores.push(score);
                bits.push(avg_bits);
            }
            let avg_perf = scores.iter().sum::<f64>() / scores.len().max(1) as f64;
            let avg_bit = bits.iter().sum::<f64>() / bits.len().max(1) as f64;
            let mut cells = vec![model.clone(), method.name()];
            cells.extend(scores.iter().map(|s| format!("{s:.2}")));
            while cells.len() < 6 {
                cells.push("-".into());
            }
            cells.push(format!("{avg_perf:.2}"));
            cells.push(format!("{avg_bit:.2}"));
            cells.push(format!("{quant_time:.2}"));
            println!("{}", tbl.row(&cells));
        }
        println!("{}", tbl.sep());
    }
    Ok(())
}
