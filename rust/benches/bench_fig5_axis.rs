//! Figure 5 (App. B) reproduction: quantization-axis design space — B and A
//! each quantized column-wise or row-wise, all four combinations. Paper:
//! LLaMA2-7B on GSM8K/MATH → here tiny-llama-s on modadd/modchain.
//!
//! Expected shape: B(col) A(row) — the default, which absorbs √s into the
//! group scales — is best or tied on the GSM8K analog; differences small.

use loraquant::bench::Table;
use loraquant::experiments::{ModelCtx, Settings};
use loraquant::loraquant::{quantize_site, LoraQuantConfig, QuantizedLora};
use loraquant::quant::QuantAxis;

fn main() -> anyhow::Result<()> {
    let mut settings = Settings::from_env();
    settings.models.retain(|m| m == "tiny-llama-s");
    let Some(model) = settings.models.first().cloned() else {
        eprintln!("bench_fig5_axis: tiny-llama-s artifacts missing — run `make artifacts`");
        return Ok(());
    };
    let ctx = ModelCtx::load(&settings, &model)?;
    println!("# Figure 5 — B/A quantization axis combinations (model {model}, 2-bit)");
    let tbl = Table::new(&[10, 6, 16, 9, 9]);
    println!(
        "{}",
        tbl.row(&["task".into(), "rho".into(), "axes".into(), "avg_bit".into(), "score".into()])
    );
    println!("{}", tbl.sep());

    for td in ctx.tasks.iter().filter(|t| t.task == "modadd" || t.task == "modchain") {
        for rho in [0.7f32, 0.9] {
            for axis in QuantAxis::all() {
                let cfg = LoraQuantConfig {
                    axis,
                    group: 128,
                    ..LoraQuantConfig::variant(2, rho)
                };
                let mut q = QuantizedLora::default();
                for (site, (a, b)) in &td.lora.sites {
                    q.sites.insert(site.clone(), quantize_site(b, a, &cfg)?);
                }
                let deltas = loraquant::model::merge::quant_deltas(&q);
                let score = ctx.eval_deltas(&deltas, &td.eval)?;
                println!(
                    "{}",
                    tbl.row(&[
                        td.task.clone(),
                        format!("{rho}"),
                        format!("{axis}"),
                        format!("{:.2}", q.avg_bits()),
                        format!("{score:.2}"),
                    ])
                );
            }
        }
        println!("{}", tbl.sep());
    }
    Ok(())
}
