//! Figure 2 reproduction: sub-LoRA split strategies (SVD vs random vs
//! norm-based) at a globally fixed h — paper setting: LLaMA2-7B on
//! GSM8K/MATH → here tiny-llama-s on modadd/modchain.
//!
//! Expected shape: SVD ≥ norm ≥ random across h.

use loraquant::bench::Table;
use loraquant::experiments::{ModelCtx, Settings};
use loraquant::loraquant::{quantize_site, HSelect, LoraQuantConfig, QuantizedLora, SplitStrategy};
use std::collections::BTreeMap;

fn main() -> anyhow::Result<()> {
    let mut settings = Settings::from_env();
    settings.models.retain(|m| m == "tiny-llama-s");
    let Some(model) = settings.models.first().cloned() else {
        eprintln!("bench_fig2_split: tiny-llama-s artifacts missing — run `make artifacts`");
        return Ok(());
    };
    let ctx = ModelCtx::load(&settings, &model)?;
    println!("# Figure 2 — split strategy vs static h (model {model})");
    let tbl = Table::new(&[10, 4, 10, 10, 10]);
    println!(
        "{}",
        tbl.row(&["task".into(), "h".into(), "svd".into(), "norm".into(), "random".into()])
    );
    println!("{}", tbl.sep());

    let strategies = [
        ("svd", SplitStrategy::Svd),
        ("norm", SplitStrategy::Norm),
        ("random", SplitStrategy::Random { seed: 17 }),
    ];
    for td in ctx.tasks.iter().filter(|t| t.task == "modadd" || t.task == "modchain") {
        for h in [2usize, 4, 6, 8, 10, 12, 14] {
            let mut scores = BTreeMap::new();
            for (name, strategy) in strategies {
                let cfg = LoraQuantConfig {
                    hselect: HSelect::Static(h),
                    strategy,
                    group: 128,
                    ..LoraQuantConfig::variant(2, 0.9)
                };
                let mut q = QuantizedLora::default();
                for (site, (a, b)) in &td.lora.sites {
                    q.sites.insert(site.clone(), quantize_site(b, a, &cfg)?);
                }
                let deltas = loraquant::model::merge::quant_deltas(&q);
                scores.insert(name, ctx.eval_deltas(&deltas, &td.eval)?);
            }
            println!(
                "{}",
                tbl.row(&[
                    td.task.clone(),
                    format!("{h}"),
                    format!("{:.2}", scores["svd"]),
                    format!("{:.2}", scores["norm"]),
                    format!("{:.2}", scores["random"]),
                ])
            );
        }
        println!("{}", tbl.sep());
    }
    Ok(())
}
