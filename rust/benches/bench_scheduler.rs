//! Continuous-batching scheduler benchmark (DESIGN.md §11): the two
//! headline numbers, measured engine-level so nothing but the decode
//! protocol differs.
//!
//! 1. **Steady-state tokens/sec** at mixed sequence lengths — one
//!    saturating Zipf-tenant queue of requests with cycling budgets and
//!    prompt lengths, decoded (a) continuously (freed lanes re-admitted
//!    mid-flight from the fair admission queue) and (b) lock-step
//!    (arrival-order batches of `LANES`, each batch running until its
//!    slowest lane drains).
//! 2. **Time-to-first-token** under that saturating trace — p50/p99 of
//!    (enqueue → first token). Continuous admits a request the moment a
//!    lane frees; lock-step holds it until its whole batch is done (a
//!    batch's outputs become visible at batch completion).
//!
//! 3. **Ragged load** (DESIGN.md §13) — one 4096-token prompt plus a
//!    dozen short requests through 2 lanes, chunked prefill vs
//!    monolithic admission at 1/2/4 threads: short-request TTFT p50/p99
//!    collapses when the long prompt streams in 128-row chunks instead
//!    of monopolizing the session for one huge admission pass.
//!
//! All paths run at 1/2/4 compute threads over the work-stealing
//! executor, so the rows double as its scaling measurement (the
//! scoped-spawn predecessor is gone from the engine; `bench_decode`'s
//! `kernel_pool_vs_scoped` rows bench the pool against it directly).
//!
//! Writes `BENCH_scheduler.json` next to the other CI snapshots.
//! Reference engine only.

#[cfg(feature = "pjrt")]
fn main() {
    eprintln!("bench_scheduler: reference engine only (PJRT decodes lock-step)");
}

#[cfg(not(feature = "pjrt"))]
fn main() -> anyhow::Result<()> {
    bench::run()
}

#[cfg(not(feature = "pjrt"))]
mod bench {
    use loraquant::clock::Clock;
    use loraquant::eval::{decode_lockstep, EngineStepper, TOKENS};
    use loraquant::model::{merge_adapter, BaseWeights, ModelConfig};
    use loraquant::runtime::Engine;
    use loraquant::scheduler::{
        run_continuous, AdmissionQueue, ContinuousConfig, LaneRequest, SessionStepper,
    };
    use loraquant::testutil::{synth_quantized_adapter, write_synth_model, Rng};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    const LANES: usize = 8;
    const REQUESTS: usize = 64;
    /// Ragged-load section: long-prompt length, prefill chunk, short count.
    const RAGGED_LONG: usize = 4096;
    const RAGGED_CHUNK: usize = 128;
    const RAGGED_SHORTS: usize = 12;

    /// Same shape as bench_decode: big enough that per-step work dominates,
    /// small enough that the whole bench is seconds.
    fn bench_config() -> ModelConfig {
        ModelConfig {
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 128,
            vocab: 64,
            seq_len: 96,
            lora_rank: 8,
            lora_alpha: 16,
            act_silu: false,
        }
    }

    struct Req {
        prompt: Vec<i32>,
        budget: usize,
        tenant: u32,
    }

    /// Mixed-length saturating workload: prompt lengths 4..=35, budgets
    /// 1..=24, Zipf-ish tenant mix.
    fn workload(cfg: &ModelConfig) -> Vec<Req> {
        let mut rng = Rng::new(97);
        (0..REQUESTS)
            .map(|i| {
                let plen = 4 + (i * 7 + 3) % 32;
                let prompt: Vec<i32> =
                    (0..plen).map(|_| 1 + rng.below(cfg.vocab - 1) as i32).collect();
                Req { prompt, budget: 1 + (i * 5 + 2) % 24, tenant: (rng.below(4)) as u32 }
            })
            .collect()
    }

    fn quantiles(mut v: Vec<Duration>) -> (Duration, Duration) {
        v.sort_unstable();
        let q = |p: f64| v[(((p * v.len() as f64).ceil() as usize).max(1) - 1).min(v.len() - 1)];
        (q(0.5), q(0.99))
    }

    pub fn run() -> anyhow::Result<()> {
        let dir = std::env::temp_dir().join(format!("lq_bench_sched_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = bench_config();
        write_synth_model(&dir, "bench", &cfg, &[LANES], 7)?;
        let base = BaseWeights::load(dir.join("bench"))?;
        let mut engine = Engine::new(&dir)?;
        engine.load_model_fwd("bench", LANES, base.cfg.param_names().len())?;
        let w = engine.upload_weights(&merge_adapter(&base, &std::collections::BTreeMap::new())?)?;
        let stored = Arc::new(synth_quantized_adapter(&cfg, 21));
        let reqs = workload(&cfg);
        let clock = Clock::real();
        let prog = format!("bench/b{LANES}");
        let mut rows: Vec<String> = Vec::new();

        println!(
            "# Continuous vs lock-step scheduler (d=64, L=2, seq_len=96, lanes={LANES}, {} requests, mixed lengths)",
            reqs.len()
        );
        println!(
            "{:>7} {:>12} {:>10} {:>10} {:>12} {:>12} {:>9} {:>9}",
            "threads", "mode", "tok/s", "steps", "ttft_p50", "ttft_p99", "tokens", "wall_ms"
        );

        for threads in [1usize, 2, 4] {
            engine.set_compute_threads(threads);

            // ---- continuous: one session, fair admission, lane reuse ----
            let mut queue = AdmissionQueue::new();
            let t0 = Instant::now();
            for (i, r) in reqs.iter().enumerate() {
                queue.push(LaneRequest {
                    id: i as u64,
                    tenant: r.tenant,
                    prompt: r.prompt.clone(),
                    budget: r.budget,
                    adapter: None,
                    enqueued: t0,
                });
            }
            let mut slot = None;
            let mut stepper = SessionStepper::new(&engine, &prog, &w, &mut slot);
            let ccfg = ContinuousConfig { lanes: LANES, seq_len: cfg.seq_len, vocab: cfg.vocab, prefill_chunk: 0 };
            let mut ttfts = Vec::with_capacity(reqs.len());
            let mut tokens = 0u64;
            let stats = run_continuous(&mut stepper, &ccfg, &mut queue, &clock, |fin| {
                ttfts.push(fin.ttft);
                tokens += fin.tokens.len() as u64;
            })?;
            let wall = t0.elapsed();
            drop(stepper);
            let (p50, p99) = quantiles(ttfts);
            let tps = tokens as f64 / wall.as_secs_f64();
            println!(
                "{threads:>7} {:>12} {tps:>10.0} {:>10} {:>12.1?} {:>12.1?} {tokens:>9} {:>9.1}",
                "continuous",
                stats.decode_steps,
                p50,
                p99,
                wall.as_secs_f64() * 1e3
            );
            rows.push(format!(
                r#"{{"mode":"continuous","threads":{threads},"tok_per_s":{tps:.0},"decode_steps":{},"admits":{},"ttft_p50_us":{},"ttft_p99_us":{},"tokens":{tokens}}}"#,
                stats.decode_steps,
                stats.admits,
                p50.as_micros(),
                p99.as_micros(),
            ));

            // ---- lock-step: arrival-order batches of LANES ----
            let t0 = Instant::now();
            let mut ttfts = Vec::with_capacity(reqs.len());
            let mut tokens = 0u64;
            let mut steps = 0u64;
            for chunk in reqs.chunks(LANES) {
                let n = chunk.len();
                let mut seqs = vec![vec![TOKENS::PAD; cfg.seq_len]; n];
                let mut pos = vec![0usize; n];
                let mut budgets = vec![0usize; n];
                for (k, r) in chunk.iter().enumerate() {
                    seqs[k][..r.prompt.len()].copy_from_slice(&r.prompt);
                    pos[k] = r.prompt.len();
                    budgets[k] = r.budget;
                }
                let mut stepper = EngineStepper::new(&engine, &prog, &w, &[]);
                let generated = decode_lockstep(
                    cfg.seq_len,
                    cfg.vocab,
                    &mut seqs,
                    &mut pos,
                    &budgets,
                    &mut stepper,
                )?;
                steps += stepper.steps();
                // lock-step visibility: a request's tokens (including its
                // first) arrive when its whole batch completes
                let done = t0.elapsed();
                for g in generated {
                    ttfts.push(done);
                    tokens += g.len() as u64;
                }
            }
            let wall = t0.elapsed();
            let (p50, p99) = quantiles(ttfts);
            let tps = tokens as f64 / wall.as_secs_f64();
            println!(
                "{threads:>7} {:>12} {tps:>10.0} {steps:>10} {:>12.1?} {:>12.1?} {tokens:>9} {:>9.1}",
                "lockstep",
                p50,
                p99,
                wall.as_secs_f64() * 1e3
            );
            rows.push(format!(
                r#"{{"mode":"lockstep","threads":{threads},"tok_per_s":{tps:.0},"decode_steps":{steps},"ttft_p50_us":{},"ttft_p99_us":{},"tokens":{tokens}}}"#,
                p50.as_micros(),
                p99.as_micros(),
            ));
        }
        engine.set_compute_threads(1);

        // ---- factor-path spot check: heterogeneous continuous session ----
        println!("\n# Factor-path continuous session (per-lane 2-bit adapters)");
        let w_base = engine
            .upload_weights(&merge_adapter(&base, &std::collections::BTreeMap::new())?)?;
        let mut queue = AdmissionQueue::new();
        let t0 = Instant::now();
        for (i, r) in reqs.iter().take(24).enumerate() {
            let src: Arc<dyn loraquant::loraquant::FactorSource> = Arc::clone(&stored);
            queue.push(LaneRequest {
                id: i as u64,
                tenant: r.tenant,
                prompt: r.prompt.clone(),
                budget: r.budget,
                adapter: Some(src),
                enqueued: t0,
            });
        }
        let mut slot = None;
        let mut stepper = SessionStepper::new(&engine, &prog, &w_base, &mut slot);
        let ccfg = ContinuousConfig { lanes: LANES, seq_len: cfg.seq_len, vocab: cfg.vocab, prefill_chunk: 0 };
        let mut tokens = 0u64;
        let stats = run_continuous(&mut stepper, &ccfg, &mut queue, &clock, |fin| {
            tokens += fin.tokens.len() as u64;
        })?;
        let wall = t0.elapsed();
        drop(stepper);
        let tps = tokens as f64 / wall.as_secs_f64();
        println!(
            "factor continuous: {tps:.0} tok/s over {} steps / {} admits ({tokens} tokens, {:.1} ms)",
            stats.decode_steps,
            stats.admits,
            wall.as_secs_f64() * 1e3
        );
        rows.push(format!(
            r#"{{"mode":"continuous_factor","threads":1,"tok_per_s":{tps:.0},"decode_steps":{},"admits":{},"tokens":{tokens}}}"#,
            stats.decode_steps,
            stats.admits,
        ));

        // ---- ragged load: one 4k prompt + 12 short requests ----
        // The chunked-prefill headline (DESIGN.md §13): with monolithic
        // admission every short request's first token waits out the full
        // 4096-row prefill; with chunking the long prompt streams in
        // RAGGED_CHUNK-row slices and the shorts admit and decode in
        // between. Short-request TTFT p50/p99 is the measurement.
        let rcfg = ModelConfig { seq_len: RAGGED_LONG + 64, ..bench_config() };
        write_synth_model(&dir, "ragged", &rcfg, &[2], 31)?;
        let rbase = BaseWeights::load(dir.join("ragged"))?;
        engine.load_model_fwd("ragged", 2, rbase.cfg.param_names().len())?;
        let rw = engine
            .upload_weights(&merge_adapter(&rbase, &std::collections::BTreeMap::new())?)?;
        let mut rng = Rng::new(113);
        let long_prompt: Vec<i32> =
            (0..RAGGED_LONG).map(|_| 1 + rng.below(rcfg.vocab - 1) as i32).collect();
        let shorts: Vec<Vec<i32>> = (0..RAGGED_SHORTS)
            .map(|s| (0..4 + s % 5).map(|_| 1 + rng.below(rcfg.vocab - 1) as i32).collect())
            .collect();
        println!(
            "\n# Ragged load: one {RAGGED_LONG}-token prompt + {RAGGED_SHORTS} short requests \
             (2 lanes, chunk={RAGGED_CHUNK} vs monolithic)"
        );
        println!(
            "{:>7} {:>12} {:>10} {:>14} {:>14} {:>9}",
            "threads", "mode", "tok/s", "short_p50", "short_p99", "wall_ms"
        );
        for threads in [1usize, 2, 4] {
            engine.set_compute_threads(threads);
            for chunk in [0usize, RAGGED_CHUNK] {
                let mut queue = AdmissionQueue::new();
                let t0 = Instant::now();
                queue.push(LaneRequest {
                    id: 0,
                    tenant: 0,
                    prompt: long_prompt.clone(),
                    budget: 4,
                    adapter: None,
                    enqueued: t0,
                });
                for (s, p) in shorts.iter().enumerate() {
                    queue.push(LaneRequest {
                        id: 1 + s as u64,
                        tenant: 1 + s as u32,
                        prompt: p.clone(),
                        budget: 3,
                        adapter: None,
                        enqueued: t0,
                    });
                }
                let mut slot = None;
                let mut stepper = SessionStepper::new(&engine, "ragged/b2", &rw, &mut slot);
                let ccfg = ContinuousConfig {
                    lanes: 2,
                    seq_len: rcfg.seq_len,
                    vocab: rcfg.vocab,
                    prefill_chunk: chunk,
                };
                let mut short_ttfts = Vec::with_capacity(RAGGED_SHORTS);
                let mut tokens = 0u64;
                run_continuous(&mut stepper, &ccfg, &mut queue, &clock, |fin| {
                    if fin.id > 0 {
                        short_ttfts.push(fin.ttft);
                    }
                    tokens += fin.tokens.len() as u64;
                })?;
                let wall = t0.elapsed();
                drop(stepper);
                let (p50, p99) = quantiles(short_ttfts);
                let tps = tokens as f64 / wall.as_secs_f64();
                let mode = if chunk == 0 { "ragged_mono" } else { "ragged_chunked" };
                println!(
                    "{threads:>7} {mode:>12} {tps:>10.0} {:>14.1?} {:>14.1?} {:>9.1}",
                    p50,
                    p99,
                    wall.as_secs_f64() * 1e3
                );
                rows.push(format!(
                    r#"{{"mode":"{mode}","threads":{threads},"chunk":{chunk},"tok_per_s":{tps:.0},"short_ttft_p50_us":{},"short_ttft_p99_us":{},"tokens":{tokens}}}"#,
                    p50.as_micros(),
                    p99.as_micros(),
                ));
            }
        }

        let json =
            format!("{{\"bench\":\"scheduler\",\"lanes\":{LANES},\"rows\":[{}]}}\n", rows.join(","));
        std::fs::write("BENCH_scheduler.json", &json)?;
        println!("\nwrote BENCH_scheduler.json ({} rows)", rows.len());
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    }
}
