//! Tiered adapter-store benchmark (DESIGN.md §14): the ISSUE-8
//! acceptance workload — a 10 000-tenant Zipf fleet served through a
//! factor cache holding ≤5% of the fleet's packed bytes, every adapter
//! spilled to the disk tier at registration. All rows replay
//! [`ScenarioSpec`]s through `scenario::run_scenario` under the virtual
//! clock — the exact code path the tiering test suite pins — so seconds
//! of simulated trace replay in milliseconds of wall time and every
//! number is reproducible.
//!
//! Rows:
//! 1. **10k-tenant headline** — tiered (5% factor cache) vs fully
//!    resident, same trace: zero decode failures, p99 latency, cache hit
//!    rate, disk-load count;
//! 2. **factor-cache budget sweep** — 1% / 5% / 25% of fleet bytes:
//!    hit rate and disk traffic vs RAM budget;
//! 3. **scripted disk latency × predictive prefetch** — every tier load
//!    parks 2 ms on the virtual clock; the arrival predictor warms
//!    factors ahead of the next expected request, trading extra disk
//!    loads for fewer request-path stalls.
//!
//! Results land in `BENCH_tiering.json` (one machine-readable snapshot
//! per run; each PR's committed snapshot is one point of the perf
//! trajectory). Reference engine only: the tiering path needs factor
//! serving, which the PJRT backend does not implement.

use loraquant::coordinator::MergeStrategy;
use loraquant::scenario::{run_scenario, ClockMode, DiskLatency, FaultPlan, ScenarioEnv, ScenarioSpec};
use loraquant::workload::WorkloadConfig;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    if cfg!(feature = "pjrt") {
        eprintln!("bench_tiering: skipped — the PJRT backend is merged-only; tiering needs factor serving");
        return Ok(());
    }
    let env = ScenarioEnv::synth("tierbench", 8)?;
    let unit = env.adapters[0].1.bytes();
    let mut json_rows: Vec<String> = Vec::new();

    // Every row shares the factor strategy: the tier pages packed factors
    // (merged strategy would page them too, but only once per merge).
    let tiered = |name: String, tenants: usize, cache_frac_pct: usize, n_requests: usize| ScenarioSpec {
        name,
        mode: ClockMode::Virtual,
        strategy: MergeStrategy::Factor,
        n_adapters: tenants,
        tiered: true,
        factor_cache_bytes: (unit * tenants * cache_frac_pct / 100).max(unit),
        max_wait: Duration::from_millis(5),
        workload: WorkloadConfig { rate: 2000.0, zipf_alpha: 1.1, n_requests, seed: 17 },
        max_new: 3,
        ..Default::default()
    };

    // ---- row 1: 10k tenants, 5% cache, vs fully resident -----------------
    println!("# Tiering — 10k-tenant Zipf fleet through a 5% factor cache (virtual time)");
    for resident in [false, true] {
        let mut spec = tiered(format!("tiered_10k/resident={resident}"), 10_000, 5, 1000);
        spec.tiered = !resident;
        let run = run_scenario(&spec, &env)?;
        let s = &run.summary;
        assert_eq!(s.failed, 0, "acceptance: zero decode failures at 10k tenants");
        println!(
            "{} | {}/{} ok failed={} | p50={:?} p99={:?} | spilled={} disk_loads={} fc_hit_rate={:.3} evictions={} | wall {:?}",
            if resident { "resident  " } else { "tiered  5%" },
            s.ok,
            s.requests,
            s.failed,
            s.latency.quantile(0.5),
            s.latency.quantile(0.99),
            s.spilled,
            s.disk_loads,
            s.factor_cache.hit_rate(),
            s.factor_cache.evictions,
            s.real_wall,
        );
        json_rows.push(format!(
            r#"{{"scenario":"headline_10k","resident":{resident},"tenants":10000,"requests":{},"ok":{},"failed":{},"p50_us":{},"p99_us":{},"spilled":{},"disk_loads":{},"fc_hits":{},"fc_misses":{},"fc_evictions":{},"wall_ms":{}}}"#,
            s.requests,
            s.ok,
            s.failed,
            s.latency.quantile(0.5).as_micros(),
            s.latency.quantile(0.99).as_micros(),
            s.spilled,
            s.disk_loads,
            s.factor_cache.hits,
            s.factor_cache.misses,
            s.factor_cache.evictions,
            s.real_wall.as_millis(),
        ));
    }

    // ---- row 2: factor-cache budget sweep --------------------------------
    println!("\n# Factor-cache budget sweep — 10k tenants, cache at 1% / 5% / 25% of fleet bytes");
    for pct in [1usize, 5, 25] {
        let spec = tiered(format!("cache_sweep/p{pct}"), 10_000, pct, 600);
        let run = run_scenario(&spec, &env)?;
        let s = &run.summary;
        println!(
            "cache={pct:>2}% | {}/{} ok | p99={:?} | disk_loads={} fc_hit_rate={:.3} evictions={}",
            s.ok,
            s.requests,
            s.latency.quantile(0.99),
            s.disk_loads,
            s.factor_cache.hit_rate(),
            s.factor_cache.evictions,
        );
        json_rows.push(format!(
            r#"{{"scenario":"cache_sweep","cache_pct":{pct},"requests":{},"ok":{},"p99_us":{},"disk_loads":{},"fc_hits":{},"fc_misses":{},"fc_evictions":{}}}"#,
            s.requests,
            s.ok,
            s.latency.quantile(0.99).as_micros(),
            s.disk_loads,
            s.factor_cache.hits,
            s.factor_cache.misses,
            s.factor_cache.evictions,
        ));
    }

    // ---- row 3: scripted disk latency × predictive prefetch --------------
    println!("\n# Scripted disk latency (2ms/load) — predictor warms factors ahead of arrivals");
    for predictive in [false, true] {
        let mut spec = tiered(format!("disk_fault/pred={predictive}"), 2000, 5, 600);
        spec.predictive_prefetch = predictive;
        spec.faults = FaultPlan {
            disk_latency: Some(DiskLatency { adapter: None, delay: Duration::from_millis(2) }),
            ..Default::default()
        };
        let run = run_scenario(&spec, &env)?;
        let s = &run.summary;
        println!(
            "predictive={predictive:<5} | {}/{} ok | p50={:?} p99={:?} | disk_loads={} fc_hit_rate={:.3}",
            s.ok,
            s.requests,
            s.latency.quantile(0.5),
            s.latency.quantile(0.99),
            s.disk_loads,
            s.factor_cache.hit_rate(),
        );
        json_rows.push(format!(
            r#"{{"scenario":"disk_fault","predictive":{predictive},"delay_ms":2,"requests":{},"ok":{},"p50_us":{},"p99_us":{},"disk_loads":{},"fc_hits":{},"fc_misses":{}}}"#,
            s.requests,
            s.ok,
            s.latency.quantile(0.5).as_micros(),
            s.latency.quantile(0.99).as_micros(),
            s.disk_loads,
            s.factor_cache.hits,
            s.factor_cache.misses,
        ));
    }

    let json = format!(
        "{{\"bench\":\"tiering\",\"model\":\"synth\",\"synthetic\":true,\"scenarios\":[{}]}}\n",
        json_rows.join(",")
    );
    std::fs::write("BENCH_tiering.json", &json)?;
    println!("\nwrote BENCH_tiering.json ({} scenario rows)", json_rows.len());
    Ok(())
}
