//! Observability overhead benchmark (DESIGN.md §16): what request-
//! lifecycle tracing costs on the serving hot path, measured on the
//! same `scenario::run_scenario` path the obs test suite pins.
//!
//! Three configurations of one storm trace (2000/s, 8 tenants):
//! 1. **off** — `spec.trace = false`: the baseline; stage accounting
//!    still runs (it is always on), but no recorder exists and no span
//!    is pushed;
//! 2. **record** — tracing on, nothing exported: the per-thread
//!    ring-buffer cost the recorder adds to every retirement;
//! 3. **export** — tracing on plus the Chrome trace JSON and Prometheus
//!    text renders, timed separately (export happens at quiescence, off
//!    the serving path).
//!
//! Results land in `BENCH_obs.json`. Reference engine only: the
//! synthetic scenario environment has no HLO artifacts for PJRT.

use loraquant::coordinator::MergeStrategy;
use loraquant::scenario::{run_scenario, ScenarioEnv, ScenarioSpec};
use loraquant::workload::WorkloadConfig;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    if cfg!(feature = "pjrt") {
        eprintln!("bench_obs: skipped — the synthetic scenario env has no PJRT artifacts");
        return Ok(());
    }
    let env = ScenarioEnv::synth("obsbench", 8)?;
    let mut json_rows: Vec<String> = Vec::new();

    println!("# Tracing overhead — 2000/s storm, 1000 requests, 8 tenants (virtual time)");
    for mode in ["off", "record", "export"] {
        let spec = ScenarioSpec {
            name: format!("obs_overhead/{mode}"),
            strategy: MergeStrategy::Merged,
            n_adapters: 8,
            max_wait: Duration::from_millis(5),
            trace: mode != "off",
            workload: WorkloadConfig { rate: 2000.0, zipf_alpha: 1.1, n_requests: 1000, seed: 7 },
            ..Default::default()
        };
        let run = run_scenario(&spec, &env)?;
        let s = &run.summary;
        let (export_wall, trace_bytes) = if mode == "export" {
            let t0 = Instant::now();
            let trace = run.trace_json();
            let metrics = run.metrics_text.clone();
            (t0.elapsed(), trace.len() + metrics.len())
        } else {
            (Duration::ZERO, 0)
        };
        let tok_s = s.tokens_generated as f64 / s.real_wall.as_secs_f64().max(1e-9);
        println!(
            "mode={mode:<7} | {}/{} ok tokens={} | {:.0} tok/s wall {:?} | spans={} export {:?} ({} B)",
            s.ok,
            s.requests,
            s.tokens_generated,
            tok_s,
            s.real_wall,
            run.spans.len(),
            export_wall,
            trace_bytes,
        );
        json_rows.push(format!(
            r#"{{"scenario":"tracing_overhead","mode":"{mode}","requests":{},"ok":{},"tokens":{},"tok_per_s":{:.1},"wall_ms":{},"spans":{},"export_us":{},"trace_bytes":{}}}"#,
            s.requests,
            s.ok,
            s.tokens_generated,
            tok_s,
            s.real_wall.as_millis(),
            run.spans.len(),
            export_wall.as_micros(),
            trace_bytes,
        ));
    }

    let json = format!(
        "{{\"bench\":\"obs\",\"model\":\"synth\",\"synthetic\":true,\"scenarios\":[{}]}}\n",
        json_rows.join(",")
    );
    std::fs::write("BENCH_obs.json", &json)?;
    println!("\nwrote BENCH_obs.json ({} scenario rows)", json_rows.len());
    Ok(())
}
