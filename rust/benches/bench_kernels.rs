//! Kernel microbenchmark (DESIGN.md §12): GFLOP/s of the cache-blocked
//! SIMD-friendly GEMMs against the retained scalar oracles
//! (`tensor::scalar`), per bitwidth.
//!
//! Three tables, one JSON snapshot (`BENCH_kernels.json`, uploaded as a
//! CI artifact next to `BENCH_decode.json`):
//!
//! 1. **Dense `matmul_flat`** — scalar oracle vs the 4×8-blocked kernel
//!    vs the persistent compute pool at 2/4 threads, on the prefill
//!    projection shape and a larger cache-pressure shape.
//! 2. **Quantized `matmul_qdequant_acc_into`** (X @ deq(Q)) — scalar
//!    oracle vs the LUT-unpacking blocked kernel at 1/2/3/8-bit RTN and
//!    1-bit sign (BinQuantized).
//! 3. **Quantized `matmul_qdequant_bt_acc_into`** (X @ deq(Q)ᵀ) — same
//!    bitwidth sweep over the dot-family kernel.
//!
//! Every timed pair is first checked bit-identical (the PR-6 determinism
//! contract): a speedup that changes bits is a bug, not a win. FLOP
//! counts are the algebraic 2·m·k·n of the GEMM; the dequant work rides
//! inside the quantized kernels' timings, so their GFLOP/s is "effective
//! dense throughput", directly comparable across bitwidths.

use loraquant::quant::{bin_quant, rtn_quant};
use loraquant::scheduler::ComputePool;
use loraquant::tensor::{
    matmul_flat, matmul_qdequant_acc_into, matmul_qdequant_bt_acc_into, scalar, DequantRows,
};
use loraquant::testutil::Rng;
use std::time::Instant;

/// Pick a rep count so each measurement runs ~80ms, then report the mean
/// per-call time in microseconds.
fn time_us(mut f: impl FnMut()) -> f64 {
    f(); // warm caches / pool workers
    let t0 = Instant::now();
    f();
    let probe = t0.elapsed().as_secs_f64();
    let reps = ((0.08 / probe.max(1e-7)) as usize).clamp(3, 20_000);
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e6 / reps as f64
}

fn gflops(m: usize, k: usize, n: usize, us: f64) -> f64 {
    (2 * m * k * n) as f64 / (us * 1e3).max(1e-9)
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}: bit mismatch at {i}: {g:e} vs {w:e}");
    }
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(606);
    let mut rows: Vec<String> = Vec::new();

    // -- 1. dense ----------------------------------------------------------
    println!("# Dense matmul_flat: scalar oracle vs blocked vs pool (GFLOP/s)");
    println!("{:>12} {:>10} {:>12} {:>10}", "shape", "variant", "us", "gflops");
    for (m, k, n) in [(88usize, 64usize, 64usize), (32, 256, 256)] {
        let a = rng.matrix(m, k, 1.0).into_vec();
        let b = rng.matrix(k, n, 1.0).into_vec();
        let mut want = vec![0.0f32; m * n];
        scalar::matmul_flat(&a, m, k, &b, n, &mut want);
        let mut c = vec![0.0f32; m * n];

        let mut emit = |variant: &str, us: f64| {
            let gf = gflops(m, k, n, us);
            println!("{:>12} {variant:>10} {us:>12.2} {gf:>10.2}", format!("{m}x{k}x{n}"));
            rows.push(format!(
                r#"{{"kernel":"dense","shape":"{m}x{k}x{n}","variant":"{variant}","us":{us:.2},"gflops":{gf:.3}}}"#
            ));
        };

        let us = time_us(|| scalar::matmul_flat(&a, m, k, &b, n, &mut c));
        assert_bits_eq(&c, &want, "dense scalar");
        emit("scalar", us);

        let us = time_us(|| matmul_flat(&a, m, k, &b, n, &mut c));
        assert_bits_eq(&c, &want, "dense blocked");
        emit("blocked", us);

        for threads in [2usize, 4] {
            let pool = ComputePool::new(threads);
            let us = time_us(|| pool.matmul_flat(&a, m, k, &b, n, &mut c).unwrap());
            assert_bits_eq(&c, &want, "dense pool");
            emit(&format!("pool{threads}"), us);
        }
    }

    // -- 2/3. quantized ----------------------------------------------------
    // Decode-ish shape: a few activation rows against a big packed matrix,
    // where the LUT unpack + axpy/dot blocking is the whole story.
    let (rows_x, k, n) = (8usize, 256usize, 256usize);
    let x = rng.matrix(rows_x, k, 1.0).into_vec();
    let group = 16usize;

    // (label, Q stored k×n for acc, Q stored n×k for bt)
    let mut quants: Vec<(String, Box<dyn DequantRows>, Box<dyn DequantRows>)> = Vec::new();
    for bits in [1u32, 2, 3, 8] {
        quants.push((
            format!("rtn{bits}"),
            Box::new(rtn_quant(&rng.matrix(k, n, 1.0), bits, group)) as Box<dyn DequantRows>,
            Box::new(rtn_quant(&rng.matrix(n, k, 1.0), bits, group)) as Box<dyn DequantRows>,
        ));
    }
    quants.push((
        "bin1".to_string(),
        Box::new(bin_quant(&rng.matrix(k, n, 1.0), group)) as Box<dyn DequantRows>,
        Box::new(bin_quant(&rng.matrix(n, k, 1.0), group)) as Box<dyn DequantRows>,
    ));

    for (family, dir) in [("qdequant_acc", "acc"), ("qdequant_bt", "bt")] {
        println!("\n# {family} ({rows_x}x{k} @ {k}x{n}): scalar oracle vs LUT-blocked");
        println!("{:>8} {:>10} {:>12} {:>10} {:>9}", "bits", "variant", "us", "gflops", "speedup");
        for (label, q_acc, q_bt) in &quants {
            let q: &dyn DequantRows = if dir == "acc" { q_acc.as_ref() } else { q_bt.as_ref() };
            let mut want = vec![0.0f32; rows_x * n];
            let mut got = vec![0.0f32; rows_x * n];
            let mut qrow: Vec<f32> = Vec::new();

            let scalar_us = if dir == "acc" {
                want.fill(0.0);
                scalar::matmul_qdequant_acc(&x, rows_x, k, q, 1.0, &mut want);
                time_us(|| {
                    got.fill(0.0);
                    scalar::matmul_qdequant_acc(&x, rows_x, k, q, 1.0, &mut got);
                })
            } else {
                want.fill(0.0);
                scalar::matmul_qdequant_bt_acc(&x, rows_x, k, q, 1.0, &mut want);
                time_us(|| {
                    got.fill(0.0);
                    scalar::matmul_qdequant_bt_acc(&x, rows_x, k, q, 1.0, &mut got);
                })
            };
            let blocked_us = if dir == "acc" {
                time_us(|| {
                    got.fill(0.0);
                    matmul_qdequant_acc_into(&x, rows_x, k, q, 1.0, &mut got, &mut qrow);
                })
            } else {
                time_us(|| {
                    got.fill(0.0);
                    matmul_qdequant_bt_acc_into(&x, rows_x, k, q, 1.0, &mut got, &mut qrow);
                })
            };
            assert_bits_eq(&got, &want, &format!("{family} {label}"));

            for (variant, us) in [("scalar", scalar_us), ("blocked", blocked_us)] {
                let gf = gflops(rows_x, k, n, us);
                let speedup = scalar_us / us.max(1e-9);
                println!("{label:>8} {variant:>10} {us:>12.2} {gf:>10.2} {speedup:>8.2}x");
                rows.push(format!(
                    r#"{{"kernel":"{family}","bits":"{label}","shape":"{rows_x}x{k}x{n}","variant":"{variant}","us":{us:.2},"gflops":{gf:.3}}}"#
                ));
            }
        }
    }

    let json = format!("{{\"bench\":\"kernels\",\"rows\":[{}]}}\n", rows.join(","));
    std::fs::write("BENCH_kernels.json", &json)?;
    println!("\nwrote BENCH_kernels.json ({} rows)", rows.len());
    Ok(())
}
