//! Table 2 (App. C) reproduction: per-task average bitwidth of the four
//! LoRAQuant variants — shows the dynamic-h rule adapting bits per adapter.

use loraquant::bench::Table;
use loraquant::experiments::{apply_method, lq, Method, ModelCtx, Settings};

fn main() -> anyhow::Result<()> {
    let settings = Settings::from_env();
    if settings.models.is_empty() {
        eprintln!("bench_table2: no model artifacts found — run `make artifacts` first");
        return Ok(());
    }
    println!("# Table 2 — per-task average bitwidth of LoRAQuant variants");
    let tbl = Table::new(&[14, 20, 10, 10, 10, 10]);
    println!(
        "{}",
        tbl.row(&[
            "model".into(),
            "variant".into(),
            "modadd".into(),
            "modchain".into(),
            "transform".into(),
            "keyword".into(),
        ])
    );
    println!("{}", tbl.sep());
    for model in &settings.models {
        let ctx = ModelCtx::load(&settings, model)?;
        let cluster: Vec<&loraquant::adapter::LoraAdapter> =
            ctx.tasks.iter().map(|t| &t.lora).collect();
        for (bits, rho) in [(2, 0.8f32), (2, 0.9), (3, 0.8), (3, 0.9)] {
            let method = Method::LoraQuant(lq(bits, rho));
            let mut cells = vec![model.clone(), format!("LoRAQuant ({bits}@{rho})")];
            for td in &ctx.tasks {
                let (_deltas, avg_bits) = apply_method(&method, td, &cluster);
                cells.push(format!("{avg_bits:.2}"));
            }
            println!("{}", tbl.row(&cells));
        }
        println!("{}", tbl.sep());
    }
    Ok(())
}
