//! Figure 3 reproduction: component ablations across the variance ratio ρ —
//! LoraQuant vs Prune (drop low sub-LoRA) vs No-Opt (skip STE) vs w/RTN
//! (1-bit RTN low sub-LoRA). Paper: LLaMA2-7B on GSM8K/MATH → here
//! tiny-llama-s on modadd/modchain.
//!
//! Expected shape: Prune and w/RTN collapse at low ρ and track each other;
//! No-Opt ≤ LoraQuant; gaps close as ρ → 1.

use loraquant::bench::Table;
use loraquant::experiments::{fig3_variant, ModelCtx, Settings};
use loraquant::loraquant::{quantize_site, QuantizedLora};

fn main() -> anyhow::Result<()> {
    let mut settings = Settings::from_env();
    settings.models.retain(|m| m == "tiny-llama-s");
    let Some(model) = settings.models.first().cloned() else {
        eprintln!("bench_fig3_ablation: tiny-llama-s artifacts missing — run `make artifacts`");
        return Ok(());
    };
    let ctx = ModelCtx::load(&settings, &model)?;
    println!("# Figure 3 — ablations across rho (model {model}, 2-bit high sub-LoRA)");
    let tbl = Table::new(&[10, 6, 11, 9, 9, 9, 9]);
    println!(
        "{}",
        tbl.row(&[
            "task".into(),
            "rho".into(),
            "loraquant".into(),
            "no_opt".into(),
            "prune".into(),
            "rtn_low".into(),
            "avg_bit".into(),
        ])
    );
    println!("{}", tbl.sep());

    let rhos = [0.1f32, 0.3, 0.5, 0.7, 0.8, 0.9, 0.95];
    for td in ctx.tasks.iter().filter(|t| t.task == "modadd" || t.task == "modchain") {
        for rho in rhos {
            let mut cells = vec![td.task.clone(), format!("{rho}")];
            let mut bits_of_main = 0.0;
            for kind in ["loraquant", "no_opt", "prune", "rtn_low"] {
                let cfg = fig3_variant(kind, rho, 128);
                let mut q = QuantizedLora::default();
                for (site, (a, b)) in &td.lora.sites {
                    q.sites.insert(site.clone(), quantize_site(b, a, &cfg)?);
                }
                if kind == "loraquant" {
                    bits_of_main = q.avg_bits();
                }
                let deltas = loraquant::model::merge::quant_deltas(&q);
                cells.push(format!("{:.2}", ctx.eval_deltas(&deltas, &td.eval)?));
            }
            cells.push(format!("{bits_of_main:.2}"));
            println!("{}", tbl.row(&cells));
        }
        println!("{}", tbl.sep());
    }
    Ok(())
}
