//! Decode benchmark (DESIGN.md §10): the KV-cache payoff, measured.
//!
//! Three questions, one JSON snapshot (`BENCH_decode.json`, uploaded as
//! a CI artifact next to `BENCH_serving.json`):
//!
//! 1. **Per-step decode cost vs sequence length** — incremental
//!    (`prefill` + `decode_step`) against the full-recompute oracle.
//!    The incremental path should stay roughly flat in `T` (its per-step
//!    work is O(L·T·d) with the attention term tiny next to the fixed
//!    projections), while the oracle's full forward grows ~linearly in
//!    `T` per step (O(L·T·d²) projections, O(L·T²·d) attention).
//! 2. The same comparison on the **factor path** (2-bit adapter applied
//!    on the activation row each step) — the per-step adapter overhead
//!    rides on a single token row, so it must not change the scaling.
//! 3. **Threaded prefill** — prompt-pass latency at 1/2/4 compute
//!    threads (row-partitioned matmuls; identical logits at any count).
//!    Since DESIGN.md §11 the threads row measures the **persistent
//!    compute pool** (`scheduler::workers::ComputePool`, two condvar
//!    handshakes per kernel), not the old per-call `thread::scope`
//!    spawns whose ~6L+1 barriers per prefill set the §10 crossover —
//!    re-run this bench to refresh the crossover claim.
//!
//! Reference engine only: the synthetic model has no HLO artifacts.

use loraquant::model::{merge_adapter, BaseWeights, ModelConfig};
use loraquant::runtime::Engine;
use loraquant::scheduler::ComputePool;
use loraquant::tensor::{matmul_flat, matmul_flat_threaded};
use loraquant::testutil::{synth_quantized_adapter, write_synth_model};
use std::time::{Duration, Instant};

/// Bigger than the unit-test model so the T-scaling is visible, small
/// enough that the whole bench is seconds.
fn bench_config() -> ModelConfig {
    ModelConfig {
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        d_ff: 128,
        vocab: 64,
        seq_len: 96,
        lora_rank: 8,
        lora_alpha: 16,
        act_silu: false,
    }
}

fn prompt(len: usize) -> Vec<Vec<i32>> {
    vec![(0..len as i32).map(|i| i % 9 + 1).collect()]
}

fn mean_us(total: Duration, n: usize) -> f64 {
    total.as_secs_f64() * 1e6 / n.max(1) as f64
}

fn main() -> anyhow::Result<()> {
    if cfg!(feature = "pjrt") {
        eprintln!("bench_decode: reference engine only (PJRT programs take full sequences)");
        return Ok(());
    }
    let dir = std::env::temp_dir().join(format!("lq_bench_decode_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = bench_config();
    write_synth_model(&dir, "bench", &cfg, &[1], 7)?;
    let base = BaseWeights::load(dir.join("bench"))?;
    let mut engine = Engine::new(&dir)?;
    engine.load_model_fwd("bench", 1, base.cfg.param_names().len())?;
    let w = engine.upload_weights(&merge_adapter(&base, &std::collections::BTreeMap::new())?)?;
    let stored = synth_quantized_adapter(&cfg, 21);
    let qf = stored.factors();

    const STEPS: usize = 6;
    const FULL_REPS: usize = 5;
    let lens = [8usize, 16, 32, 64, 88];
    let mut rows: Vec<String> = Vec::new();

    println!("# Incremental decode vs full recompute (d=64, L=2, seq_len=96, bsz=1)");
    println!(
        "{:>5} {:>16} {:>16} {:>16} {:>9}",
        "seq", "inc_step_us", "inc+adapter_us", "full_step_us", "speedup"
    );
    for &len in &lens {
        let seqs = prompt(len);
        let lane_lens = [len];

        // incremental, merged weights: prefill once, then timed steps
        let (mut state, _) = engine.prefill("bench/b1", &seqs, &lane_lens, &w, &[])?;
        let _ = engine.decode_step(&mut state, &w, &[], &[5])?; // warm scratch
        let t0 = Instant::now();
        for _ in 0..STEPS {
            let _ = engine.decode_step(&mut state, &w, &[], &[5])?;
        }
        let inc_us = mean_us(t0.elapsed(), STEPS);

        // incremental, factor path (2-bit adapter on the activation row)
        let adapters = [Some(&qf)];
        let (mut fstate, _) = engine.prefill("bench/b1", &seqs, &lane_lens, &w, &adapters)?;
        let _ = engine.decode_step(&mut fstate, &w, &adapters, &[5])?;
        let t0 = Instant::now();
        for _ in 0..STEPS {
            let _ = engine.decode_step(&mut fstate, &w, &adapters, &[5])?;
        }
        let inc_factor_us = mean_us(t0.elapsed(), STEPS);

        // full recompute: one old-style decode step at trace length `len`
        let flat: Vec<i32> = seqs[0].clone();
        let _ = engine.forward("bench/b1", &flat, &[1, len], &w)?; // warm
        let t0 = Instant::now();
        for _ in 0..FULL_REPS {
            let _ = engine.forward("bench/b1", &flat, &[1, len], &w)?;
        }
        let full_us = mean_us(t0.elapsed(), FULL_REPS);

        let speedup = full_us / inc_us.max(1e-9);
        println!(
            "{len:>5} {inc_us:>16.1} {inc_factor_us:>16.1} {full_us:>16.1} {speedup:>8.1}x"
        );
        rows.push(format!(
            r#"{{"mode":"incremental","seq":{len},"per_step_us":{inc_us:.1}}}"#
        ));
        rows.push(format!(
            r#"{{"mode":"incremental_factor","seq":{len},"per_step_us":{inc_factor_us:.1}}}"#,
        ));
        rows.push(format!(r#"{{"mode":"full","seq":{len},"per_step_us":{full_us:.1}}}"#));
    }

    println!("\n# Threaded prefill over the persistent compute pool (prompt length 88)");
    let seqs = prompt(88);
    let lane_lens = [88usize];
    for threads in [1usize, 2, 4] {
        engine.set_compute_threads(threads);
        let _ = engine.prefill("bench/b1", &seqs, &lane_lens, &w, &[])?; // warm
        let t0 = Instant::now();
        const PRE_REPS: usize = 5;
        for _ in 0..PRE_REPS {
            let _ = engine.prefill("bench/b1", &seqs, &lane_lens, &w, &[])?;
        }
        let us = mean_us(t0.elapsed(), PRE_REPS);
        println!("threads={threads} prefill_us={us:.1} (persistent pool)");
        rows.push(format!(
            r#"{{"mode":"prefill_threads_pool","threads":{threads},"seq":88,"prefill_us":{us:.1}}}"#
        ));
    }
    engine.set_compute_threads(1);

    // Kernel-level baseline: the persistent pool vs the legacy per-call
    // `thread::scope` spawns on the prefill projection shape (88 rows ×
    // d 64 @ 64×64) — the §10→§11 crossover claim, measured directly.
    println!("\n# Kernel: persistent pool vs scoped-spawn matmul (88x64 @ 64x64)");
    let (m, k, n) = (88usize, 64usize, 64usize);
    let a: Vec<f32> = (0..m * k).map(|i| (i % 13) as f32 * 0.1 - 0.6).collect();
    let b: Vec<f32> = (0..k * n).map(|i| (i % 11) as f32 * 0.1 - 0.5).collect();
    let mut c = vec![0.0f32; m * n];
    const MM_REPS: usize = 400;
    let t0 = Instant::now();
    for _ in 0..MM_REPS {
        matmul_flat(&a, m, k, &b, n, &mut c);
    }
    let serial_us = mean_us(t0.elapsed(), MM_REPS);
    println!("threads=1 serial_us={serial_us:.2}");
    rows.push(format!(r#"{{"mode":"kernel_serial","threads":1,"matmul_us":{serial_us:.2}}}"#));
    for threads in [2usize, 4] {
        let pool = ComputePool::new(threads);
        pool.matmul_flat(&a, m, k, &b, n, &mut c).unwrap(); // warm the workers
        let t0 = Instant::now();
        for _ in 0..MM_REPS {
            pool.matmul_flat(&a, m, k, &b, n, &mut c).unwrap();
        }
        let pool_us = mean_us(t0.elapsed(), MM_REPS);
        let t0 = Instant::now();
        for _ in 0..MM_REPS {
            matmul_flat_threaded(&a, m, k, &b, n, &mut c, threads);
        }
        let scoped_us = mean_us(t0.elapsed(), MM_REPS);
        println!(
            "threads={threads} pool_us={pool_us:.2} scoped_spawn_us={scoped_us:.2} ({:.1}x)",
            scoped_us / pool_us.max(1e-9)
        );
        rows.push(format!(
            r#"{{"mode":"kernel_pool_vs_scoped","threads":{threads},"pool_us":{pool_us:.2},"scoped_us":{scoped_us:.2}}}"#
        ));
    }

    let json = format!(
        "{{\"bench\":\"decode\",\"steps_per_point\":{STEPS},\"rows\":[{}]}}\n",
        rows.join(",")
    );
    std::fs::write("BENCH_decode.json", &json)?;
    println!("\nwrote BENCH_decode.json ({} rows)", rows.len());
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
