//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf): quantization pipeline
//! stages, dequant+merge, packing, SVD, STE — the L3 costs that gate
//! adapter registration and cache-miss latency.

use loraquant::bench::{bench, bench_for};
use loraquant::loraquant::{quantize_site, LoraQuantConfig, SteConfig};
use loraquant::quant::{bin_quant, pack_codes, rtn_dequant, rtn_quant, unpack_codes};
use loraquant::tensor::matmul;
use loraquant::testutil::Rng;
use std::time::Duration;

fn main() {
    let mut rng = Rng::new(2024);
    let (b, a) = rng.lora_pair(512, 128, 16, 0.7); // the w2 site (largest)
    let budget = Duration::from_millis(600);

    println!("# Perf — L3 hot paths (site 512x128 r16 unless noted)");

    let r = bench_for("svd_lowrank_product(512x16,16x128)", budget, || {
        loraquant::linalg::svd_lowrank_product(&b, &a)
    });
    println!("{r}");

    let r = bench_for("rtn_quant 2-bit g128 (16x512)", budget, || {
        rtn_quant(&b.transpose(), 2, 128)
    });
    println!("{r}  [{:.1} Melem/s]", r.throughput((16 * 512) as f64) / 1e6);

    let q = rtn_quant(&b.transpose(), 2, 128);
    let r = bench_for("rtn_dequant 2-bit g128 (16x512)", budget, || rtn_dequant(&q));
    println!("{r}  [{:.1} Melem/s]", r.throughput((16 * 512) as f64) / 1e6);

    let r = bench_for("bin_quant g128 (16x512)", budget, || bin_quant(&b.transpose(), 128));
    println!("{r}");

    let codes: Vec<u8> = (0..8192).map(|i| (i % 4) as u8).collect();
    let r = bench_for("pack_codes 2-bit (8192)", budget, || pack_codes(&codes, 2));
    println!("{r}  [{:.1} Melem/s]", r.throughput(8192.0) / 1e6);
    let packed = pack_codes(&codes, 2);
    let r = bench_for("unpack_codes 2-bit (8192)", budget, || unpack_codes(&packed, 2, 8192));
    println!("{r}  [{:.1} Melem/s]", r.throughput(8192.0) / 1e6);

    let ste = SteConfig::default();
    let bcol = b.col(0);
    let arow = a.row(0).to_vec();
    let r = bench_for("ste optimize_component 100 steps (512+128)", budget, || {
        loraquant::loraquant::optimize_component(
            &bcol,
            &arow,
            loraquant::loraquant::VecQuant::Rtn { bits: 2, group: 128 },
            loraquant::loraquant::VecQuant::Rtn { bits: 2, group: 128 },
            &ste,
        )
    });
    println!("{r}");

    let cfg = LoraQuantConfig::default();
    let r = bench("quantize_site full pipeline (512x128 r16)", 1, 10, || {
        quantize_site(&b, &a, &cfg).unwrap()
    });
    println!("{r}");

    let site = quantize_site(&b, &a, &cfg).unwrap();
    let r = bench_for("dequant_delta (512x128)", budget, || site.dequant_delta());
    println!("{r}");

    let r = bench_for("matmul 512x16 @ 16x128", budget, || matmul(&b, &a));
    println!(
        "{r}  [{:.2} GFLOP/s]",
        r.throughput(2.0 * 512.0 * 16.0 * 128.0) / 1e9
    );
}
