//! Figure 4 reproduction: dynamic variance-ratio h selection vs a static
//! global h — plotted as (avg_bits, score) frontier points. Paper:
//! LLaMA2-7B on GSM8K/MATH → here tiny-llama-s on modadd/modchain.
//!
//! Expected shape: at matched avg-bits above ~1.5, the dynamic rule
//! dominates the static one.

use loraquant::bench::Table;
use loraquant::experiments::{ModelCtx, Settings};
use loraquant::loraquant::{quantize_site, HSelect, LoraQuantConfig, QuantizedLora};

fn main() -> anyhow::Result<()> {
    let mut settings = Settings::from_env();
    settings.models.retain(|m| m == "tiny-llama-s");
    let Some(model) = settings.models.first().cloned() else {
        eprintln!("bench_fig4_hselect: tiny-llama-s artifacts missing — run `make artifacts`");
        return Ok(());
    };
    let ctx = ModelCtx::load(&settings, &model)?;
    println!("# Figure 4 — dynamic (ratio) vs static h selection (model {model})");
    let tbl = Table::new(&[10, 9, 12, 9, 9]);
    println!(
        "{}",
        tbl.row(&[
            "task".into(),
            "rule".into(),
            "param".into(),
            "avg_bit".into(),
            "score".into(),
        ])
    );
    println!("{}", tbl.sep());

    for td in ctx.tasks.iter().filter(|t| t.task == "modadd" || t.task == "modchain") {
        // dynamic: rho from 0.1 to 0.95 in increments of 0.05 (paper text)
        for k in 2..=19 {
            let rho = k as f32 * 0.05;
            let cfg = LoraQuantConfig { group: 128, ..LoraQuantConfig::variant(2, rho) };
            let (bits, score) = run(&ctx, td, &cfg)?;
            println!(
                "{}",
                tbl.row(&[
                    td.task.clone(),
                    "ratio".into(),
                    format!("rho={rho:.2}"),
                    format!("{bits:.2}"),
                    format!("{score:.2}"),
                ])
            );
        }
        // static: h in 1..=12 (paper text)
        for h in 1..=12usize {
            let cfg = LoraQuantConfig {
                hselect: HSelect::Static(h),
                group: 128,
                ..LoraQuantConfig::variant(2, 0.9)
            };
            let (bits, score) = run(&ctx, td, &cfg)?;
            println!(
                "{}",
                tbl.row(&[
                    td.task.clone(),
                    "static".into(),
                    format!("h={h}"),
                    format!("{bits:.2}"),
                    format!("{score:.2}"),
                ])
            );
        }
        println!("{}", tbl.sep());
    }
    Ok(())
}

fn run(
    ctx: &ModelCtx,
    td: &loraquant::experiments::TaskData,
    cfg: &LoraQuantConfig,
) -> anyhow::Result<(f64, f64)> {
    let mut q = QuantizedLora::default();
    for (site, (a, b)) in &td.lora.sites {
        q.sites.insert(site.clone(), quantize_site(b, a, cfg)?);
    }
    let deltas = loraquant::model::merge::quant_deltas(&q);
    Ok((q.avg_bits(), ctx.eval_deltas(&deltas, &td.eval)?))
}
