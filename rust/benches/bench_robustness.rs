//! Fault-contained serving benchmark (DESIGN.md §15): what the ISSUE-9
//! robustness machinery costs and what it buys, measured on the same
//! `scenario::run_scenario` path the robustness test suite pins, under
//! the virtual clock — so every number is reproducible.
//!
//! Rows:
//! 1. **deadline storm** — a 2000/s burst against a 15 ms per-request
//!    deadline vs the same trace deadline-free: completed vs timed-out
//!    counts and tail latency (timeouts bound the tail by construction);
//! 2. **fault soak** — scripted merge panic + permanently failing disk
//!    loads (→ quarantine) in one tiered trace: containment counters
//!    (respawns, quarantines, per-kind failures) and survivor throughput;
//! 3. **retry ladder** — a transient 2-failure disk fault with 0 vs 2
//!    retries: the retry budget converts hard failures into +backoff
//!    latency;
//! 4. **load shedding** — a depth-2 admission cap under a 4000/s burst
//!    vs uncapped: sheds traded for bounded queue delay.
//!
//! Results land in `BENCH_robustness.json`. Reference engine only: the
//! synthetic scenario environment has no HLO artifacts for PJRT.

use loraquant::coordinator::MergeStrategy;
use loraquant::scenario::{
    run_scenario, DiskError, FaultPlan, ScenarioEnv, ScenarioSpec, ScriptedPanic,
};
use loraquant::workload::WorkloadConfig;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    if cfg!(feature = "pjrt") {
        eprintln!("bench_robustness: skipped — the synthetic scenario env has no PJRT artifacts");
        return Ok(());
    }
    let env = ScenarioEnv::synth("robustbench", 8)?;
    let unit = env.adapters[0].1.bytes();
    let mut json_rows: Vec<String> = Vec::new();

    // ---- row 1: deadline storm vs deadline-free --------------------------
    println!("# Deadline storm — 2000/s Zipf burst, 15ms deadline vs none (virtual time)");
    for with_deadline in [false, true] {
        let spec = ScenarioSpec {
            name: format!("deadline_storm/deadline={with_deadline}"),
            strategy: MergeStrategy::Merged,
            max_wait: Duration::from_secs(1),
            request_timeout: with_deadline.then(|| Duration::from_millis(15)),
            workload: WorkloadConfig { rate: 2000.0, zipf_alpha: 1.1, n_requests: 600, seed: 7 },
            n_adapters: 8,
            ..Default::default()
        };
        let run = run_scenario(&spec, &env)?;
        let s = &run.summary;
        println!(
            "deadline={:<5} | {}/{} ok timeouts={} | p50={:?} p99={:?} max={:?} | wall {:?}",
            with_deadline,
            s.ok,
            s.requests,
            s.timeouts,
            s.latency.quantile(0.5),
            s.latency.quantile(0.99),
            s.latency.max(),
            s.real_wall,
        );
        json_rows.push(format!(
            r#"{{"scenario":"deadline_storm","deadline_ms":{},"requests":{},"ok":{},"timeouts":{},"p50_us":{},"p99_us":{},"max_us":{},"wall_ms":{}}}"#,
            if with_deadline { 15 } else { 0 },
            s.requests,
            s.ok,
            s.timeouts,
            s.latency.quantile(0.5).as_micros(),
            s.latency.quantile(0.99).as_micros(),
            s.latency.max().as_micros(),
            s.real_wall.as_millis(),
        ));
    }

    // ---- row 2: fault soak — panic + permanent disk failure --------------
    println!("\n# Fault soak — scripted merge panic (adapter 1) + permanent disk failure (adapter 2)");
    for faulted in [false, true] {
        let spec = ScenarioSpec {
            name: format!("fault_soak/faulted={faulted}"),
            strategy: MergeStrategy::Merged,
            tiered: true,
            factor_cache_bytes: unit * 16,
            n_adapters: 8,
            disk_retries: if faulted { 2 } else { 0 },
            disk_backoff: Duration::from_millis(1),
            workload: WorkloadConfig { rate: 400.0, zipf_alpha: 1.1, n_requests: 400, seed: 11 },
            faults: if faulted {
                FaultPlan {
                    panic: Some(ScriptedPanic { adapter: 1, first_n: 1 }),
                    disk_error: Some(DiskError { adapter: Some(2), first_n: u32::MAX }),
                    ..Default::default()
                }
            } else {
                FaultPlan::default()
            },
            ..Default::default()
        };
        let run = run_scenario(&spec, &env)?;
        let s = &run.summary;
        println!(
            "faulted={:<5} | {}/{} ok failed={:?} | respawns={} quarantined={} disk_retries={} | p99={:?} | wall {:?}",
            faulted,
            s.ok,
            s.requests,
            s.failed_by_kind,
            s.worker_respawns,
            s.quarantined,
            s.disk_retries,
            s.latency.quantile(0.99),
            s.real_wall,
        );
        let by_kind: Vec<String> = s
            .failed_by_kind
            .iter()
            .map(|(k, v)| format!(r#""{k}":{v}"#))
            .collect();
        json_rows.push(format!(
            r#"{{"scenario":"fault_soak","faulted":{faulted},"requests":{},"ok":{},"failed":{},"failed_by_kind":{{{}}},"worker_respawns":{},"quarantined":{},"disk_retries":{},"p99_us":{},"wall_ms":{}}}"#,
            s.requests,
            s.ok,
            s.failed,
            by_kind.join(","),
            s.worker_respawns,
            s.quarantined,
            s.disk_retries,
            s.latency.quantile(0.99).as_micros(),
            s.real_wall.as_millis(),
        ));
    }

    // ---- row 3: retry ladder — transient fault, 0 vs 2 retries -----------
    println!("\n# Retry ladder — first 2 loads of adapter 2 fail; retry budget 0 vs 2 (1ms backoff)");
    for retries in [0u32, 2] {
        let spec = ScenarioSpec {
            name: format!("retry_ladder/retries={retries}"),
            strategy: MergeStrategy::Factor,
            tiered: true,
            factor_cache_bytes: unit * 16,
            n_adapters: 8,
            round_robin: true,
            disk_retries: retries,
            disk_backoff: Duration::from_millis(1),
            workload: WorkloadConfig { rate: 400.0, zipf_alpha: 1.1, n_requests: 400, seed: 13 },
            faults: FaultPlan {
                disk_error: Some(DiskError { adapter: Some(2), first_n: 2 }),
                ..Default::default()
            },
            ..Default::default()
        };
        let run = run_scenario(&spec, &env)?;
        let s = &run.summary;
        println!(
            "retries={retries} | {}/{} ok failed={} quarantined={} disk_retries={} | p99={:?}",
            s.ok,
            s.requests,
            s.failed,
            s.quarantined,
            s.disk_retries,
            s.latency.quantile(0.99),
        );
        json_rows.push(format!(
            r#"{{"scenario":"retry_ladder","retries":{retries},"requests":{},"ok":{},"failed":{},"quarantined":{},"disk_retries":{},"p99_us":{}}}"#,
            s.requests,
            s.ok,
            s.failed,
            s.quarantined,
            s.disk_retries,
            s.latency.quantile(0.99).as_micros(),
        ));
    }

    // ---- row 4: load shedding — depth-2 cap vs uncapped ------------------
    println!("\n# Load shedding — 4000/s burst, admission cap 2 vs uncapped");
    for cap in [None, Some(2usize)] {
        let spec = ScenarioSpec {
            name: format!("shed/cap={cap:?}"),
            strategy: MergeStrategy::Factor,
            queue_cap: cap,
            workload: WorkloadConfig { rate: 4000.0, zipf_alpha: 1.1, n_requests: 400, seed: 17 },
            n_adapters: 8,
            ..Default::default()
        };
        let run = run_scenario(&spec, &env)?;
        let s = &run.summary;
        println!(
            "cap={:<7} | {}/{} ok sheds={} | p50={:?} p99={:?} | wall {:?}",
            format!("{cap:?}"),
            s.ok,
            s.requests,
            s.sheds,
            s.latency.quantile(0.5),
            s.latency.quantile(0.99),
            s.real_wall,
        );
        json_rows.push(format!(
            r#"{{"scenario":"shed","cap":{},"requests":{},"ok":{},"sheds":{},"p50_us":{},"p99_us":{},"wall_ms":{}}}"#,
            cap.map_or(0, |c| c),
            s.requests,
            s.ok,
            s.sheds,
            s.latency.quantile(0.5).as_micros(),
            s.latency.quantile(0.99).as_micros(),
            s.real_wall.as_millis(),
        ));
    }

    let json = format!(
        "{{\"bench\":\"robustness\",\"model\":\"synth\",\"synthetic\":true,\"scenarios\":[{}]}}\n",
        json_rows.join(",")
    );
    std::fs::write("BENCH_robustness.json", &json)?;
    println!("\nwrote BENCH_robustness.json ({} scenario rows)", json_rows.len());
    Ok(())
}
