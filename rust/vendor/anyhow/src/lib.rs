//! Vendored, offline subset of the `anyhow` error-handling API.
//!
//! The build environment has no crates.io access, so this crate
//! re-implements exactly the surface `loraquant` uses — [`Error`],
//! [`Result`], the [`Context`] extension trait, and the [`anyhow!`] /
//! [`bail!`] macros — with the same semantics:
//!
//! * any `std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?`, capturing its source chain;
//! * `.context(..)` / `.with_context(..)` push a new message onto the
//!   chain (and lift `Option` into `Result`);
//! * `{e}` displays the outermost message, `{e:#}` the whole chain
//!   joined with `": "`, and `{e:?}` a multi-line report.
//!
//! Swapping the real crate back in is a one-line Cargo.toml change; no
//! source edits are needed.

use std::fmt;

/// `Result` alias defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-chain error type (the outermost message first).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create from any displayable message.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self { msg: msg.to_string(), source: None }
    }

    /// Wrap `self` under a new outermost message.
    pub fn context(self, msg: impl fmt::Display) -> Self {
        Self { msg: msg.to_string(), source: Some(Box::new(self)) }
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur.msg.as_str())
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, msg) in self.chain().enumerate() {
                if i > 0 {
                    f.write_str(": ")?;
                }
                f.write_str(msg)?;
            }
            Ok(())
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if self.source.is_some() {
            f.write_str("\n\nCaused by:")?;
            for msg in self.chain().skip(1) {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

// NOTE: like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes the blanket `From` below
// coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut top = Error::msg(&e);
        // capture the std source chain as messages
        let mut src = e.source();
        let mut tail: &mut Error = &mut top;
        while let Some(s) = src {
            tail.source = Some(Box::new(Error::msg(s)));
            tail = tail.source.as_deref_mut().unwrap();
            src = s.source();
        }
        top
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    /// Attach a context message (lifts `Option::None` into an error).
    fn context<C: fmt::Display>(self, msg: C) -> Result<T>;
    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T> {
        self.map_err(|e| e.into().context(msg))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn std_source_chain_is_captured() {
        #[derive(Debug)]
        struct Outer(std::io::Error);
        impl fmt::Display for Outer {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                f.write_str("outer failed")
            }
        }
        impl std::error::Error for Outer {
            fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
                Some(&self.0)
            }
        }
        let e: Error = Outer(io_err()).into();
        assert_eq!(format!("{e:#}"), "outer failed: missing");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e: Result<()> = Err(Error::msg("root"));
        let e = e.context("mid").unwrap_err().context("top");
        assert_eq!(format!("{e}"), "top");
        assert_eq!(format!("{e:#}"), "top: mid: root");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context_lifts() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("k={}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "k=7");
        assert_eq!(Some(3u32).context("x").unwrap(), 3);
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("code {}", 42);
        assert_eq!(e.to_string(), "code 42");
        fn bails() -> Result<()> {
            bail!("nope {n}", n = 1);
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope 1");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
