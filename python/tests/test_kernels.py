"""Pallas kernels (L1) vs the pure-jnp oracle — the core build-time
correctness signal, including hypothesis sweeps over shapes/dtypes."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import binary, lora_apply, ref, rtn

RNG = np.random.default_rng(0)


def randm(r, n, scale=1.0):
    return jnp.asarray(RNG.normal(size=(r, n)).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# RTN kernel vs oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("shape,group", [((16, 128), 64), ((8, 64), 32), ((16, 256), 128)])
def test_rtn_quant_matches_ref(bits, shape, group):
    w = randm(*shape)
    c1, s1, z1 = ref.rtn_quant(w, bits, group)
    c2, s2, z2 = rtn.rtn_quant_pallas(w, bits, group)
    assert bool(jnp.all(c1 == c2))
    np.testing.assert_allclose(s1, s2, rtol=1e-6)
    np.testing.assert_allclose(z1, z2, rtol=1e-6)


def test_rtn_dequant_matches_ref():
    w = randm(16, 128)
    c, s, z = ref.rtn_quant(w, 2, 64)
    np.testing.assert_allclose(
        ref.rtn_dequant(c, s, z, 64), rtn.rtn_dequant_pallas(c, s, z, 64), rtol=1e-6
    )


def test_rtn_roundtrip_error_bounded():
    w = randm(8, 128)
    for bits in [2, 4, 8]:
        c, s, z = ref.rtn_quant(w, bits, 64)
        wd = ref.rtn_dequant(c, s, z, 64)
        err = jnp.abs(wd - w).max()
        step = s.max()
        assert err <= step * 1.01, f"bits={bits}"


def test_rtn_degenerate_group_reconstructs_constant():
    w = jnp.full((2, 64), 3.5, jnp.float32)
    c, s, z = ref.rtn_quant(w, 2, 32)
    wd = ref.rtn_dequant(c, s, z, 32)
    np.testing.assert_allclose(wd, w, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.sampled_from([1, 4, 8]),
    groups=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([16, 32, 64]),
    bits=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_rtn_hypothesis_roundtrip(rows, groups, group, bits, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(rows, groups * group)).astype(np.float32))
    c1, s1, z1 = ref.rtn_quant(w, bits, group)
    c2, s2, z2 = rtn.rtn_quant_pallas(w, bits, group)
    assert bool(jnp.all(c1 == c2))
    np.testing.assert_allclose(s1, s2, rtol=1e-6)
    # dequant error bounded by scale
    wd = ref.rtn_dequant(c1, s1, z1, group)
    per_group_err = jnp.abs(wd - w).reshape(rows, groups, group).max(axis=-1)
    assert bool(jnp.all(per_group_err <= s1 * 1.01 + 1e-7))


# ---------------------------------------------------------------------------
# Binary kernel vs oracle
# ---------------------------------------------------------------------------
def test_bin_quant_matches_ref():
    w = randm(16, 128)
    s1, sc1 = ref.bin_quant(w, 64)
    s2, sc2 = binary.bin_quant_pallas(w, 64)
    assert bool(jnp.all(s1 == s2))
    np.testing.assert_allclose(sc1, sc2, rtol=1e-6)
    np.testing.assert_allclose(
        ref.bin_dequant(s1, sc1, 64), binary.bin_dequant_pallas(s1, sc1, 64), rtol=1e-6
    )


@settings(max_examples=25, deadline=None)
@given(
    rows=st.sampled_from([1, 2, 8]),
    group=st.sampled_from([8, 32, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_bin_hypothesis_l1_scale_optimal(rows, group, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(rows, 2 * group)).astype(np.float32))
    signs, scale = ref.bin_quant(w, group)
    base = float(jnp.sum((ref.bin_dequant(signs, scale, group) - w) ** 2))
    for f in [0.9, 1.1]:
        alt = float(jnp.sum((ref.bin_dequant(signs, scale * f, group) - w) ** 2))
        assert alt >= base - 1e-6


# ---------------------------------------------------------------------------
# Packing
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([8, 32, 64, 128]), seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_pack_roundtrips(n, seed):
    rng = np.random.default_rng(seed)
    c2 = jnp.asarray(rng.integers(0, 4, size=(4, n)).astype(np.int32))
    assert bool(jnp.all(ref.unpack2(ref.pack2(c2), n) == c2))
    s1 = jnp.asarray((rng.integers(0, 2, size=(4, n)) * 2 - 1).astype(np.int32))
    assert bool(jnp.all(ref.unpack1(ref.pack1(s1), n) == s1))


# ---------------------------------------------------------------------------
# Fused quantized sub-LoRA apply (the hot-spot kernel)
# ---------------------------------------------------------------------------
def fused_case(B, n, m, h, rl, g, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(B, n)).astype(np.float32))
    ah = jnp.asarray(rng.normal(size=(h, n)).astype(np.float32))
    bh = jnp.asarray(rng.normal(size=(h, m)).astype(np.float32))
    al = jnp.asarray(rng.normal(size=(rl, n)).astype(np.float32))
    bl = jnp.asarray(rng.normal(size=(rl, m)).astype(np.float32))
    ahc, ahs, ahz = ref.rtn_quant(ah, 2, g)
    bhc, bhs, bhz = ref.rtn_quant(bh, 2, g)
    als, alsc = ref.bin_quant(al, g)
    bls, blsc = ref.bin_quant(bl, g)
    args = (
        x,
        ref.pack2(ahc), ahs, ahz,
        ref.pack2(bhc), bhs, bhz,
        ref.pack1(als), alsc,
        ref.pack1(bls), blsc,
    )
    return args, g


@pytest.mark.parametrize(
    "B,n,m,h,rl",
    [(8, 128, 128, 4, 12), (8, 128, 256, 4, 12), (1, 64, 128, 2, 6), (8, 128, 512, 8, 8)],
)
def test_fused_kernel_matches_ref(B, n, m, h, rl):
    args, g = fused_case(B, n, m, h, rl, 64)
    y_ref = ref.lora_apply_quant_ref(*args, g)
    y_ker = lora_apply.lora_apply_pallas(*args, group=g)
    np.testing.assert_allclose(y_ref, y_ker, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    B=st.sampled_from([1, 4, 8]),
    m=st.sampled_from([128, 256]),
    h=st.sampled_from([2, 4, 8]),
    rl=st.sampled_from([8, 12]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fused_kernel_hypothesis(B, m, h, rl, seed):
    args, g = fused_case(B, 128, m, h, rl, 64, seed)
    y_ref = ref.lora_apply_quant_ref(*args, g)
    y_ker = lora_apply.lora_apply_pallas(*args, group=g)
    np.testing.assert_allclose(y_ref, y_ker, atol=1e-4)


def test_vmem_estimate_within_budget():
    # real-TPU shape check: the largest site at serving batch
    bytes_ = lora_apply.vmem_bytes_estimate(bsz=8, n=512, m=512, h=8, rl=8, group=64)
    assert bytes_ < 16 << 20, f"VMEM estimate {bytes_} exceeds 16 MiB"
