"""L2 model tests: shapes, LoRA algebra, merge equivalence, AOT interface,
and the tasks/tensorfile contracts shared with rust."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import tasks, tensorfile


@pytest.fixture(scope="module")
def cfg():
    return M.ModelConfig(name="test", d_model=32, n_layers=2, n_heads=2, d_ff=64)


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def test_forward_shapes(cfg, params):
    toks = jnp.zeros((3, cfg.seq_len), jnp.int32)
    logits = M.forward(cfg, params, toks)
    assert logits.shape == (3, cfg.seq_len, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_lora_zero_init_is_identity(cfg, params):
    lora = M.init_lora(cfg, jax.random.PRNGKey(1))
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 40, size=(2, cfg.seq_len)), jnp.int32)
    l0 = M.forward(cfg, params, toks)
    l1 = M.forward(cfg, params, toks, lora)
    np.testing.assert_allclose(l0, l1, atol=1e-6)


def test_merge_equals_unmerged_forward(cfg, params):
    # after training-like perturbation, merged weights == lora-applied fwd
    key = jax.random.PRNGKey(2)
    lora = M.init_lora(cfg, key)
    lora = {k: v + 0.02 * jax.random.normal(jax.random.PRNGKey(hash(k) % 2**31), v.shape)
            for k, v in lora.items()}
    toks = jnp.asarray(np.random.default_rng(1).integers(0, 40, size=(2, cfg.seq_len)), jnp.int32)
    l_lora = M.forward(cfg, params, toks, lora)
    l_merged = M.forward(cfg, M.merge_lora(cfg, params, lora), toks)
    np.testing.assert_allclose(l_lora, l_merged, atol=1e-4)


def test_param_names_cover_exactly(cfg, params):
    names = M.param_names(cfg)
    assert set(names) == set(params.keys())
    # rust mirror expects this count: 2 + L*10 + 3
    assert len(names) == 2 + cfg.n_layers * 10 + 3


def test_fwd_flat_positional_interface(cfg, params):
    f = M.fwd_flat(cfg)
    toks = jnp.zeros((1, cfg.seq_len), jnp.int32)
    flat = [params[n] for n in M.param_names(cfg)]
    (logits,) = f(toks, *flat)
    np.testing.assert_allclose(logits, M.forward(cfg, params, toks), atol=1e-6)


def test_loss_masks_prompt(cfg, params):
    toks, mask = tasks.make_batch("modadd", np.random.default_rng(0), 4)
    loss = M.loss_fn(cfg, params, None, jnp.asarray(toks), jnp.asarray(mask))
    assert float(loss) > 0
    # zero mask -> zero loss contribution (division guard)
    zloss = M.loss_fn(cfg, params, None, jnp.asarray(toks), jnp.zeros_like(jnp.asarray(mask)))
    assert float(zloss) == 0.0


def test_forward_with_taps_captures_all_sites(cfg, params):
    toks = jnp.zeros((2, cfg.seq_len), jnp.int32)
    _, taps = M.forward_with_taps(cfg, params, toks)
    assert set(taps.keys()) == set(M.lora_site_names(cfg))
    assert taps["l0.w2"].shape == (2 * cfg.seq_len, cfg.d_ff)


# ---------------------------------------------------------------------------
# tasks contract (mirrored in rust/src/eval/tasks.rs)
# ---------------------------------------------------------------------------
def test_task_token_contract():
    assert (tasks.PAD, tasks.BOS, tasks.EOS, tasks.SEP, tasks.MARK) == (0, 1, 2, 3, 4)
    assert tasks.DIGIT0 == 5 and tasks.LETTER0 == 15 and tasks.OP0 == 31
    assert tasks.VOCAB == 64 and tasks.SEQ_LEN == 32


@pytest.mark.parametrize("task", tasks.TASKS + ["copy"])
def test_generators_fit_sequence(task):
    rng = np.random.default_rng(0)
    for _ in range(50):
        p, a = tasks.GENERATORS[task](rng)
        toks, mask = tasks.assemble(p, a)
        assert toks.shape == (tasks.SEQ_LEN,)
        assert mask.sum() == len(a) + 1  # answer + EOS
        assert all(0 <= t < tasks.VOCAB for t in toks)


def test_transform_ops_are_permutation_safe():
    for op in tasks.OPS:
        out = tasks._apply_op(op, [1, 2, 3, 4, 5, 6])
        assert len(out) == 6
        assert all(0 <= x < 16 for x in out)


def test_eval_set_layout():
    prompts, plens, refs, rlens = tasks.make_eval_set("modadd", np.random.default_rng(0), 10)
    for i in range(10):
        assert prompts[i, 0] == tasks.BOS
        assert prompts[i, plens[i] - 1] == tasks.SEP
        assert (prompts[i, plens[i]:] == tasks.PAD).all()
        assert rlens[i] == 2


# ---------------------------------------------------------------------------
# tensorfile contract (mirrored in rust/src/adapter/fmt.rs)
# ---------------------------------------------------------------------------
def test_tensorfile_roundtrip(tmp_path):
    data = {
        "a": np.arange(6, dtype=np.float32).reshape(2, 3),
        "b": np.array([-1, 2], np.int32),
        "c": np.array([[0, 255]], np.uint8),
    }
    path = tmp_path / "t.bin"
    tensorfile.save(path, data)
    back = tensorfile.load(path)
    assert set(back) == set(data)
    for k in data:
        np.testing.assert_array_equal(back[k], data[k])


def test_tensorfile_rejects_bad_magic(tmp_path):
    path = tmp_path / "bad.bin"
    path.write_bytes(b"XXXX" + b"\0" * 8)
    with pytest.raises(ValueError):
        tensorfile.load(path)
