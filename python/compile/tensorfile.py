"""`tensorfile` — the little-endian tensor container shared with rust.

Layout (all little-endian):

    magic   b"LQTF"
    version u32                  (currently 1)
    count   u32
    then per tensor:
      name_len u16, name utf-8 bytes
      dtype    u8      (0 = f32, 1 = i32, 2 = u8)
      ndim     u8
      dims     u32 * ndim
      data     raw little-endian, row-major

The rust decoder lives in rust/src/adapter/fmt.rs; keep them in sync.
"""

import struct

import numpy as np

MAGIC = b"LQTF"
VERSION = 1
_DTYPES = {0: np.float32, 1: np.int32, 2: np.uint8}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.uint8): 2}


def save(path, tensors):
    """tensors: dict[str, np.ndarray] (f32/i32/u8)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _CODES:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _CODES[arr.dtype], arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())


def load(path):
    """Returns dict[str, np.ndarray]."""
    out = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        version, count = struct.unpack("<II", f.read(8))
        if version != VERSION:
            raise ValueError(f"{path}: unsupported version {version}")
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            dtype, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            dt = np.dtype(_DTYPES[dtype])
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(n * dt.itemsize), dtype=dt)
            out[name] = data.reshape(dims).copy()
    return out
