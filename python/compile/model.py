"""L2: tiny decoder-only transformer with LoRA on every linear layer.

This is the build-time JAX model. Its forward pass is AOT-lowered to HLO
text (aot.py) with the **weights as runtime inputs**, so the rust serving
path can swap LoRA-merged weights per adapter without recompiling.

Weight schema (canonical name order is `param_names(cfg)`; the rust side
mirrors it in rust/src/model/schema.rs — keep in sync):

    embed [V, d]          token embedding
    pos   [T, d]          learned positional embedding
    l{i}.ln1.g/.b [d]     pre-attention layernorm
    l{i}.wq/.wk/.wv/.wo [d, d]
    l{i}.ln2.g/.b [d]     pre-FFN layernorm
    l{i}.w1 [d, f]        FFN in
    l{i}.w2 [f, d]        FFN out
    lnf.g/.b [d]          final layernorm
    head  [d, V]          output projection (untied)

Convention: activations are row vectors, y = x @ W. The paper's LoRA
(B[m,r], A[r,n], y = (W + BA) x_col) therefore enters as
x @ W + s * (x @ A^T) @ B^T with s = alpha / r.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import tasks


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = tasks.VOCAB
    seq_len: int = tasks.SEQ_LEN
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    act: str = "gelu"      # "gelu" | "silu"
    lora_rank: int = 16
    lora_alpha: int = 32


# The three "models" of the paper's evaluation (DESIGN.md §2 substitution).
MODELS = {
    "tiny-llama-s": ModelConfig(name="tiny-llama-s", d_model=128, n_layers=4, n_heads=4, d_ff=512, act="gelu"),
    "tiny-llama-m": ModelConfig(name="tiny-llama-m", d_model=192, n_layers=6, n_heads=6, d_ff=768, act="gelu"),
    "tiny-mistral-s": ModelConfig(name="tiny-mistral-s", d_model=128, n_layers=4, n_heads=4, d_ff=384, act="silu"),
}

# Linear sites that receive LoRA, per layer (the paper: "every linear layer").
LORA_SITES = ["wq", "wk", "wv", "wo", "w1", "w2"]


def site_shapes(cfg):
    """{site: (n_in, m_out)} for one layer."""
    d, f = cfg.d_model, cfg.d_ff
    return {"wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d), "w1": (d, f), "w2": (f, d)}


def param_names(cfg):
    names = ["embed", "pos"]
    for i in range(cfg.n_layers):
        names += [f"l{i}.ln1.g", f"l{i}.ln1.b"]
        names += [f"l{i}.{w}" for w in ["wq", "wk", "wv", "wo"]]
        names += [f"l{i}.ln2.g", f"l{i}.ln2.b", f"l{i}.w1", f"l{i}.w2"]
    names += ["lnf.g", "lnf.b", "head"]
    return names


def lora_site_names(cfg):
    return [f"l{i}.{s}" for i in range(cfg.n_layers) for s in LORA_SITES]


def init_params(cfg, key):
    """Base-model init (scaled-normal, zeros for biases, ones for LN gains)."""
    p = {}
    keys = iter(jax.random.split(key, 6 * cfg.n_layers + 8))
    std = 0.02
    p["embed"] = jax.random.normal(next(keys), (cfg.vocab, cfg.d_model)) * std
    p["pos"] = jax.random.normal(next(keys), (cfg.seq_len, cfg.d_model)) * std
    for i in range(cfg.n_layers):
        p[f"l{i}.ln1.g"] = jnp.ones((cfg.d_model,))
        p[f"l{i}.ln1.b"] = jnp.zeros((cfg.d_model,))
        for w in ["wq", "wk", "wv", "wo"]:
            p[f"l{i}.{w}"] = jax.random.normal(next(keys), (cfg.d_model, cfg.d_model)) * std
        p[f"l{i}.ln2.g"] = jnp.ones((cfg.d_model,))
        p[f"l{i}.ln2.b"] = jnp.zeros((cfg.d_model,))
        p[f"l{i}.w1"] = jax.random.normal(next(keys), (cfg.d_model, cfg.d_ff)) * std
        p[f"l{i}.w2"] = jax.random.normal(next(keys), (cfg.d_ff, cfg.d_model)) * std
    p["lnf.g"] = jnp.ones((cfg.d_model,))
    p["lnf.b"] = jnp.zeros((cfg.d_model,))
    p["head"] = jax.random.normal(next(keys), (cfg.d_model, cfg.vocab)) * std
    return p


def init_lora(cfg, key):
    """LoRA init per paper convention: A ~ N(0, 1/r), B = 0."""
    lp = {}
    shapes = site_shapes(cfg)
    keys = iter(jax.random.split(key, len(lora_site_names(cfg))))
    r = cfg.lora_rank
    for i in range(cfg.n_layers):
        for s in LORA_SITES:
            n_in, m_out = shapes[s]
            k = next(keys)
            lp[f"l{i}.{s}.A"] = jax.random.normal(k, (r, n_in)) / np.sqrt(r)
            lp[f"l{i}.{s}.B"] = jnp.zeros((m_out, r))
    return lp


def _layernorm(x, g, b):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return g * (x - mu) / jnp.sqrt(var + 1e-5) + b


def _act(x, kind):
    return jax.nn.gelu(x) if kind == "gelu" else jax.nn.silu(x)


def _linear(x, w, lora, name, scaling):
    y = x @ w
    if lora is not None:
        a, b = lora[f"{name}.A"], lora[f"{name}.B"]
        y = y + scaling * ((x @ a.T) @ b.T)
    return y


def forward(cfg, params, tokens, lora=None):
    """logits f32[B, T, V] from tokens i32[B, T].

    `lora` (optional) is the un-merged LoRA parameter dict used during
    training; the serving path instead merges deltas into `params`.
    """
    return _forward_impl(cfg, params, tokens, lora, None)


def forward_with_taps(cfg, params, tokens, lora=None):
    """Forward that also returns {site: input activation [B*T, n_in]} — used
    to capture GPTQ calibration activations at train time."""
    taps = {}
    logits = _forward_impl(cfg, params, tokens, lora, taps)
    return logits, taps


def _forward_impl(cfg, params, tokens, lora, taps):
    s = cfg.lora_alpha / cfg.lora_rank
    bsz, t = tokens.shape
    x = params["embed"][tokens] + params["pos"][None, :t, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    hd = cfg.d_model // cfg.n_heads

    def lin(x2, i, site):
        name = f"l{i}.{site}"
        if taps is not None:
            taps[name] = x2.reshape(-1, x2.shape[-1])
        return _linear(x2, params[name], lora, name, s)

    for i in range(cfg.n_layers):
        hx = _layernorm(x, params[f"l{i}.ln1.g"], params[f"l{i}.ln1.b"])
        q = lin(hx, i, "wq").reshape(bsz, t, cfg.n_heads, hd)
        k = lin(hx, i, "wk").reshape(bsz, t, cfg.n_heads, hd)
        v = lin(hx, i, "wv").reshape(bsz, t, cfg.n_heads, hd)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
        att = jnp.where(mask[None, None], att, -1e9)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(bsz, t, cfg.d_model)
        x = x + lin(o, i, "wo")
        hx = _layernorm(x, params[f"l{i}.ln2.g"], params[f"l{i}.ln2.b"])
        hx2 = _act(lin(hx, i, "w1"), cfg.act)
        x = x + lin(hx2, i, "w2")
    x = _layernorm(x, params["lnf.g"], params["lnf.b"])
    return x @ params["head"]


def merge_lora(cfg, params, lora):
    """W_eff = W + s * (B A)^T per site — what the rust coordinator does
    after dequantization (mirrored in rust/src/model/merge.rs)."""
    s = cfg.lora_alpha / cfg.lora_rank
    out = dict(params)
    for name in lora_site_names(cfg):
        a, b = lora[f"{name}.A"], lora[f"{name}.B"]
        out[name] = params[name] + s * (b @ a).T
    return out


def fwd_flat(cfg):
    """Forward taking a flat positional param list, for AOT lowering.

    Signature: f(tokens, *params_in_param_names_order) -> (logits,).
    """
    names = param_names(cfg)

    def f(tokens, *flat):
        params = dict(zip(names, flat))
        return (forward(cfg, params, tokens),)

    return f


def loss_fn(cfg, params, lora, tokens, mask):
    """Next-token CE over the answer region (mask == 1)."""
    logits = forward(cfg, params, tokens, lora)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, 1:]
    return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
