"""Pallas kernels for sign-based binary quantization (paper Eq. 8).

Scale is the group-wise L1 mean, which minimizes ||W - S*sign(W)||_F
(Rastegari et al., 2016). interpret=True for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .rtn import ROW_BLOCK, _row_grid


def _bin_quant_kernel(w_ref, signs_ref, scale_ref, *, group):
    w = w_ref[...]
    r, n = w.shape
    g = w.reshape(r, n // group, group)
    scale_ref[...] = jnp.mean(jnp.abs(g), axis=-1).astype(jnp.float32)
    signs_ref[...] = jnp.where(w >= 0, 1, -1).astype(jnp.int32)


def _bin_dequant_kernel(signs_ref, scale_ref, out_ref, *, group):
    s = signs_ref[...].astype(jnp.float32)
    r, n = s.shape
    g = s.reshape(r, n // group, group)
    out_ref[...] = (scale_ref[...][..., None] * g).reshape(r, n)


def bin_quant_pallas(w, group):
    """w: f32[r, n] -> (signs i32[r, n] in {-1,+1}, scale f32[r, n//group])."""
    r, n = w.shape
    steps, blk = _row_grid(r)
    ng = n // group
    kern = functools.partial(_bin_quant_kernel, group=group)
    return pl.pallas_call(
        kern,
        grid=(steps,),
        in_specs=[pl.BlockSpec((blk, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((blk, n), lambda i: (i, 0)),
            pl.BlockSpec((blk, ng), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, n), jnp.int32),
            jax.ShapeDtypeStruct((r, ng), jnp.float32),
        ],
        interpret=True,
    )(w)


def bin_dequant_pallas(signs, scale, group):
    r, n = signs.shape
    steps, blk = _row_grid(r)
    ng = n // group
    kern = functools.partial(_bin_dequant_kernel, group=group)
    return pl.pallas_call(
        kern,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((blk, n), lambda i: (i, 0)),
            pl.BlockSpec((blk, ng), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.float32),
        interpret=True,
    )(signs, scale)
