"""Pure-jnp reference oracle for the Pallas kernels.

Everything here is the *definition of correct*: the Pallas kernels
(`rtn.py`, `binary.py`, `lora_apply.py`) and the rust implementations
(rust/src/quant/) are tested against these functions.

Quantization conventions (shared across all three layers):

* RTN is group-wise along the **last axis**: each row of a 2-D matrix is cut
  into contiguous groups of `group` elements; each group gets an fp scale S
  and an integer zero-point Z with  dequant(q) = S * (q - Z)  (paper Eq. 6-7).
* Binary quantization is sign-based with the L1-optimal scale
  S = mean(|w|) per group (paper Eq. 8, XNOR-Net).
* Packing is little-endian **within a byte** along the last axis:
  2-bit code j sits at bits 2*(j%4) of byte j//4; 1-bit code j at bit j%8.
"""

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# RTN (round-to-nearest) group-wise quantization — paper §3.2, Eqs. 6-7
# ---------------------------------------------------------------------------
def rtn_quant(w, bits, group):
    """w: f32[..., n] with n % group == 0.

    Returns (codes i32[..., n], scale f32[..., n//group], zero i32-valued
    f32[..., n//group]).  Degenerate all-equal groups reconstruct the
    constant exactly (scale=constant, code 1, zero 0).
    """
    qmax = float(2**bits - 1)
    shape = w.shape
    g = w.reshape(shape[:-1] + (shape[-1] // group, group))
    lo = g.min(axis=-1)
    hi = g.max(axis=-1)
    rng = hi - lo
    degenerate = rng <= 0
    # Degenerate (constant) groups: scale = the constant, code 1, zero 0 ->
    # dequant reproduces the constant exactly (matches rust/src/quant/rtn.rs).
    deg_scale = jnp.where(lo == 0, 1.0, lo)
    scale = jnp.where(degenerate, deg_scale, rng / qmax)
    # q_min = 0, so Z = round(-lo / S)
    zero = jnp.round(-lo / scale)
    q = jnp.clip(jnp.round(g / scale[..., None]) + zero[..., None], 0.0, qmax)
    deg_code = jnp.where(lo == 0, 0.0, 1.0)
    q = jnp.where(degenerate[..., None], deg_code[..., None], q)
    zero = jnp.where(degenerate, 0.0, zero)
    return (
        q.reshape(shape).astype(jnp.int32),
        scale.astype(jnp.float32),
        zero.astype(jnp.float32),
    )


def rtn_dequant(codes, scale, zero, group):
    shape = codes.shape
    g = codes.reshape(shape[:-1] + (shape[-1] // group, group)).astype(jnp.float32)
    w = scale[..., None] * (g - zero[..., None])
    return w.reshape(shape)


# ---------------------------------------------------------------------------
# Sign binarization — paper §3.2, Eq. 8
# ---------------------------------------------------------------------------
def bin_quant(w, group):
    """Returns (signs i32[..., n] in {-1,+1}, scale f32[..., n//group])."""
    shape = w.shape
    g = w.reshape(shape[:-1] + (shape[-1] // group, group))
    scale = jnp.mean(jnp.abs(g), axis=-1)
    signs = jnp.where(g >= 0, 1, -1).astype(jnp.int32)
    return signs.reshape(shape), scale.astype(jnp.float32)


def bin_dequant(signs, scale, group):
    shape = signs.shape
    g = signs.reshape(shape[:-1] + (shape[-1] // group, group)).astype(jnp.float32)
    return (scale[..., None] * g).reshape(shape)


# ---------------------------------------------------------------------------
# Bit packing (little-endian within byte, along last axis)
# ---------------------------------------------------------------------------
def pack2(codes):
    """codes i32[..., n] in 0..3, n % 4 == 0 -> u8[..., n//4]."""
    shape = codes.shape
    c = codes.reshape(shape[:-1] + (shape[-1] // 4, 4)).astype(jnp.uint8)
    return c[..., 0] | (c[..., 1] << 2) | (c[..., 2] << 4) | (c[..., 3] << 6)


def unpack2(packed, n):
    """u8[..., n//4] -> i32[..., n]."""
    p = packed[..., None]
    shifts = jnp.array([0, 2, 4, 6], dtype=jnp.uint8)
    c = (p >> shifts) & jnp.uint8(3)
    return c.reshape(packed.shape[:-1] + (n,)).astype(jnp.int32)


def pack1(signs):
    """signs i32[..., n] in {-1,+1}, n % 8 == 0 -> u8[..., n//8] (bit=1 <=> +1)."""
    shape = signs.shape
    bits = (signs > 0).astype(jnp.uint8)
    b = bits.reshape(shape[:-1] + (shape[-1] // 8, 8))
    shifts = jnp.arange(8, dtype=jnp.uint8)
    return jnp.sum(b << shifts, axis=-1).astype(jnp.uint8)


def unpack1(packed, n):
    """u8[..., n//8] -> i32[..., n] in {-1,+1}."""
    p = packed[..., None]
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (p >> shifts) & jnp.uint8(1)
    signs = bits.astype(jnp.int32) * 2 - 1
    return signs.reshape(packed.shape[:-1] + (n,))


# ---------------------------------------------------------------------------
# Fused quantized sub-LoRA apply (the L1 hot spot) — reference
# ---------------------------------------------------------------------------
def lora_apply_dense(x, ah, bh_t, al, bl_t):
    """y[B,m] = x @ AhT @ BhT' + x @ AlT @ BlT'  with A*[h,n], B*_t[h,m]."""
    yh = (x @ ah.T) @ bh_t
    yl = (x @ al.T) @ bl_t
    return yh + yl


def lora_apply_quant_ref(
    x,
    ah_codes, ah_scale, ah_zero,
    bh_codes, bh_scale, bh_zero,
    al_packed, al_scale,
    bl_packed, bl_scale,
    group,
):
    """Reference for the fused kernel: unpack -> dequant -> dual matmul.

    ah_codes u8[h, n//4] (2-bit packed), bh_codes u8[h, m//4];
    al_packed u8[rl, n//8], bl_packed u8[rl, m//8]; scales per group of
    `group` along the unpacked axis.
    """
    n = ah_scale.shape[-1] * group
    m = bh_scale.shape[-1] * group
    ah = rtn_dequant(unpack2(ah_codes, n), ah_scale, ah_zero, group)
    bh_t = rtn_dequant(unpack2(bh_codes, m), bh_scale, bh_zero, group)
    al = bin_dequant(unpack1(al_packed, n), al_scale, group)
    bl_t = bin_dequant(unpack1(bl_packed, m), bl_scale, group)
    return lora_apply_dense(x, ah, bh_t, al, bl_t)
