"""Pallas kernels (L1) and their pure-jnp oracle (ref)."""
from . import binary, lora_apply, ref, rtn  # noqa: F401
