"""Pallas kernels for group-wise RTN quantization / dequantization.

These run in interpret=True mode (CPU PJRT cannot execute Mosaic custom
calls); the BlockSpec structure is still written as it would be for a real
TPU: one grid step per row-block, group reductions vectorized in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-block processed per grid step. Rows are independent in group-wise RTN,
# so this is a pure VMEM-tiling knob: each step stages ROW_BLOCK*(n + n/group
# overheads) floats through VMEM.
ROW_BLOCK = 8


def _rtn_quant_kernel(w_ref, codes_ref, scale_ref, zero_ref, *, bits, group):
    w = w_ref[...]
    r, n = w.shape
    qmax = float(2**bits - 1)
    g = w.reshape(r, n // group, group)
    lo = g.min(axis=-1)
    hi = g.max(axis=-1)
    rng = hi - lo
    degenerate = rng <= 0
    # Degenerate groups: see ref.rtn_quant (kept in lockstep with rust).
    deg_scale = jnp.where(lo == 0, 1.0, lo)
    scale = jnp.where(degenerate, deg_scale, rng / qmax)
    zero = jnp.round(-lo / scale)
    q = jnp.clip(jnp.round(g / scale[..., None]) + zero[..., None], 0.0, qmax)
    deg_code = jnp.where(lo == 0, 0.0, 1.0)
    q = jnp.where(degenerate[..., None], deg_code[..., None], q)
    codes_ref[...] = q.reshape(r, n).astype(jnp.int32)
    scale_ref[...] = scale.astype(jnp.float32)
    zero_ref[...] = jnp.where(degenerate, 0.0, zero).astype(jnp.float32)


def _rtn_dequant_kernel(codes_ref, scale_ref, zero_ref, out_ref, *, group):
    c = codes_ref[...].astype(jnp.float32)
    r, n = c.shape
    g = c.reshape(r, n // group, group)
    w = scale_ref[...][..., None] * (g - zero_ref[...][..., None])
    out_ref[...] = w.reshape(r, n)


def _row_grid(r):
    assert r % ROW_BLOCK == 0 or r < ROW_BLOCK, f"rows {r} vs block {ROW_BLOCK}"
    blk = ROW_BLOCK if r % ROW_BLOCK == 0 else r
    return r // blk, blk


def rtn_quant_pallas(w, bits, group):
    """Group-wise RTN quantize via Pallas. w: f32[r, n], n % group == 0."""
    r, n = w.shape
    steps, blk = _row_grid(r)
    ng = n // group
    kern = functools.partial(_rtn_quant_kernel, bits=bits, group=group)
    return pl.pallas_call(
        kern,
        grid=(steps,),
        in_specs=[pl.BlockSpec((blk, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((blk, n), lambda i: (i, 0)),
            pl.BlockSpec((blk, ng), lambda i: (i, 0)),
            pl.BlockSpec((blk, ng), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, n), jnp.int32),
            jax.ShapeDtypeStruct((r, ng), jnp.float32),
            jax.ShapeDtypeStruct((r, ng), jnp.float32),
        ],
        interpret=True,
    )(w)


def rtn_dequant_pallas(codes, scale, zero, group):
    """Inverse of rtn_quant_pallas. codes: i32[r, n]."""
    r, n = codes.shape
    steps, blk = _row_grid(r)
    ng = n // group
    kern = functools.partial(_rtn_dequant_kernel, group=group)
    return pl.pallas_call(
        kern,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((blk, n), lambda i: (i, 0)),
            pl.BlockSpec((blk, ng), lambda i: (i, 0)),
            pl.BlockSpec((blk, ng), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.float32),
        interpret=True,
    )(codes, scale, zero)
