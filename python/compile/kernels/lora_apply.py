"""Fused quantized sub-LoRA apply — the L1 hot-spot kernel.

Computes, for one linear site with a LoRAQuant-compressed adapter,

    y[B, m] = x @ dequant2(Ah)^T @ dequant2(Bh^T)        (high sub-LoRA)
            + x @ dequant1(Al)^T @ dequant1(Bl^T)        (low  sub-LoRA)

where the high factors are 2-bit RTN codes packed 4-per-byte and the low
factors are 1-bit sign codes packed 8-per-byte, with per-group fp32 scales
(group along the unpacked axis). All unpacking happens **in VMEM** with
vectorized shifts/masks, so HBM only ever sees the packed pages — this is
the TPU restatement of Punica's SGMV insight (amortize the adapter gather
over the token batch), see DESIGN.md §Hardware-Adaptation.

Grid: one step per m-block of the output. The x/A-side operands are
replicated across steps (index_map -> block 0) and the small rank-h
intermediate t = x @ Ah^T is recomputed per step; on TPU this trades a few
B*n*h FLOPs for streaming only one Bh^T/Bl^T page per step through VMEM.

interpret=True everywhere: CPU PJRT cannot run Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

M_BLOCK = 128


# NOTE: scalar shift amounts (not constant arrays) — pallas kernels may not
# capture array constants, so unpacking stacks per-shift lanes explicitly.
def _unpack2(p, n):
    lanes = [(p >> jnp.uint8(2 * j)) & jnp.uint8(3) for j in range(4)]
    c = jnp.stack(lanes, axis=-1)
    return c.reshape(p.shape[:-1] + (n,)).astype(jnp.float32)


def _unpack1(p, n):
    lanes = [(p >> jnp.uint8(j)) & jnp.uint8(1) for j in range(8)]
    bits = jnp.stack(lanes, axis=-1)
    return (bits.astype(jnp.float32) * 2.0 - 1.0).reshape(p.shape[:-1] + (n,))


def _dequant_rtn(codes, scale, zero, group):
    r, n = codes.shape
    g = codes.reshape(r, n // group, group)
    return (scale[..., None] * (g - zero[..., None])).reshape(r, n)


def _dequant_bin(signs, scale, group):
    r, n = signs.shape
    g = signs.reshape(r, n // group, group)
    return (scale[..., None] * g).reshape(r, n)


def _lora_apply_kernel(
    x_ref,
    ah_c_ref, ah_s_ref, ah_z_ref,
    bh_c_ref, bh_s_ref, bh_z_ref,
    al_p_ref, al_s_ref,
    bl_p_ref, bl_s_ref,
    y_ref,
    *, n, group,
):
    mb = y_ref.shape[1]
    x = x_ref[...]
    # High sub-LoRA: unpack 2-bit codes, dequant, dual matmul.
    ah = _dequant_rtn(_unpack2(ah_c_ref[...], n), ah_s_ref[...], ah_z_ref[...], group)
    bh_t = _dequant_rtn(_unpack2(bh_c_ref[...], mb), bh_s_ref[...], bh_z_ref[...], group)
    th = jnp.dot(x, ah.T)            # [B, h]   (rank-sized, recomputed per step)
    y = jnp.dot(th, bh_t)            # [B, mb]
    # Low sub-LoRA: unpack sign bits, dequant, dual matmul.
    al = _dequant_bin(_unpack1(al_p_ref[...], n), al_s_ref[...], group)
    bl_t = _dequant_bin(_unpack1(bl_p_ref[...], mb), bl_s_ref[...], group)
    tl = jnp.dot(x, al.T)            # [B, rl]
    y = y + jnp.dot(tl, bl_t)
    y_ref[...] = y


def lora_apply_pallas(
    x,
    ah_codes, ah_scale, ah_zero,
    bh_codes, bh_scale, bh_zero,
    al_packed, al_scale,
    bl_packed, bl_scale,
    *, group,
):
    """Fused quantized sub-LoRA apply.

    Shapes: x f32[B, n]; ah_codes u8[h, n//4]; bh_codes u8[h, m//4];
    al_packed u8[rl, n//8]; bl_packed u8[rl, m//8]; scales/zeros
    f32[rank, axis//group]. Returns y f32[B, m]. m % M_BLOCK == 0 or m < M_BLOCK.
    """
    bsz, n = x.shape
    h = ah_codes.shape[0]
    rl = al_packed.shape[0]
    m = bh_scale.shape[1] * group
    mb = M_BLOCK if m % M_BLOCK == 0 else m
    steps = m // mb
    ngg, mgg = n // group, mb // group
    rep = lambda j: (0, 0)           # operand replicated across m-blocks
    stp = lambda j: (0, j)           # operand tiled along m
    kern = functools.partial(_lora_apply_kernel, n=n, group=group)
    return pl.pallas_call(
        kern,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((bsz, n), rep),           # x
            pl.BlockSpec((h, n // 4), rep),        # ah codes
            pl.BlockSpec((h, ngg), rep),           # ah scale
            pl.BlockSpec((h, ngg), rep),           # ah zero
            pl.BlockSpec((h, mb // 4), stp),       # bh codes   (streamed)
            pl.BlockSpec((h, mgg), stp),           # bh scale
            pl.BlockSpec((h, mgg), stp),           # bh zero
            pl.BlockSpec((rl, n // 8), rep),       # al packed
            pl.BlockSpec((rl, ngg), rep),          # al scale
            pl.BlockSpec((rl, mb // 8), stp),      # bl packed  (streamed)
            pl.BlockSpec((rl, mgg), stp),          # bl scale
        ],
        out_specs=pl.BlockSpec((bsz, mb), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, m), jnp.float32),
        interpret=True,
    )(
        x,
        ah_codes, ah_scale, ah_zero,
        bh_codes, bh_scale, bh_zero,
        al_packed, al_scale,
        bl_packed, bl_scale,
    )


def vmem_bytes_estimate(bsz, n, m, h, rl, group):
    """Static VMEM footprint estimate per grid step (fp32 unpacked in VMEM).

    Used by DESIGN.md/EXPERIMENTS.md to check the 16 MiB budget for real-TPU
    shapes; interpret-mode wallclock is not a TPU proxy.
    """
    mb = min(m, M_BLOCK)
    f32 = 4
    resident = (
        bsz * n * f32                      # x
        + h * (n // 4 + mb // 4)           # packed 2-bit pages
        + rl * (n // 8 + mb // 8)          # packed 1-bit pages
        + (2 * h + rl) * (n // group + mb // group) * f32   # scales/zeros
        + (h + rl) * (n + mb) * f32        # unpacked factors (worst case)
        + bsz * (h + rl) * f32             # t intermediates
        + bsz * mb * f32                   # y block
    )
    return resident
