"""Build-time training: pretrain tiny base models, then per-task LoRAs.

Mimics the paper's setup (§4.1) at tiny scale: the base model is pretrained
on a generic format-learning corpus (`copy`), frozen, and a rank-16 LoRA is
trained per task — so, as in the paper's "LoRA carries the skill" regime,
the adapters are *essential* (the frozen base scores ~0 on every task).

Outputs (under artifacts/):
    <model>/base.bin             base weights           (tensorfile)
    <model>/<task>.lora.bin      LoRA A/B per site      (tensorfile)
    <model>/<task>.eval.bin      held-out eval set      (tensorfile)
    <model>/<task>.calib.bin     per-site input acts    (tensorfile, GPTQ)
    <model>/meta.bin             config scalars

Runs once via `make artifacts`; never on the request path.
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import tasks, tensorfile


# ---------------------------------------------------------------------------
# Minimal Adam (optax is unavailable in this image)
# ---------------------------------------------------------------------------
def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(grads, state, params, lr, b1=0.9, b2=0.95, eps=1e-8, clip=1.0):
    # global-norm clip (paper: norm threshold 1)
    gn = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, clip / (gn + 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)
    t = state["t"] + 1
    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    bc1, bc2 = 1 - b1**tf, 1 - b2**tf
    new = jax.tree.map(
        lambda p, mm, vv: p - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps), params, m, v
    )
    return new, {"m": m, "v": v, "t": t}


def cosine_lr(base_lr, step, total, warmup_frac=0.3, alpha_f=0.01):
    """cosine_with_warmup as in the paper's Appendix A."""
    warm = max(1, int(total * warmup_frac))
    if step < warm:
        return base_lr * (step + 1) / warm
    p = (step - warm) / max(1, total - warm)
    return base_lr * (alpha_f + (1 - alpha_f) * 0.5 * (1 + np.cos(np.pi * p)))


# ---------------------------------------------------------------------------
# Training loops
# ---------------------------------------------------------------------------
def pretrain_base(cfg, rng, steps, batch_size, lr, log_every=100):
    params = M.init_params(cfg, jax.random.PRNGKey(hash(cfg.name) % 2**31))

    @jax.jit
    def step_fn(params, opt, tokens, mask, lr_now):
        loss, grads = jax.value_and_grad(lambda p: M.loss_fn(cfg, p, None, tokens, mask))(params)
        params, opt = adam_update(grads, opt, params, lr_now)
        return params, opt, loss

    opt = adam_init(params)
    for step in range(steps):
        toks, mask = tasks.make_batch("copy", rng, batch_size)
        params, opt, loss = step_fn(params, opt, jnp.asarray(toks), jnp.asarray(mask),
                                    cosine_lr(lr, step, steps))
        if step % log_every == 0 or step == steps - 1:
            print(f"  [pretrain {cfg.name}] step {step:4d} loss {float(loss):.4f}", flush=True)
    return params


def train_lora(cfg, params, task, rng, steps, batch_size, lr, log_every=100):
    lora = M.init_lora(cfg, jax.random.PRNGKey((hash(cfg.name + task)) % 2**31))

    @jax.jit
    def step_fn(lora, opt, tokens, mask, lr_now):
        loss, grads = jax.value_and_grad(lambda lp: M.loss_fn(cfg, params, lp, tokens, mask))(lora)
        lora, opt = adam_update(grads, opt, lora, lr_now)
        return lora, opt, loss

    opt = adam_init(lora)
    for step in range(steps):
        toks, mask = tasks.make_batch(task, rng, batch_size)
        lora, opt, loss = step_fn(lora, opt, jnp.asarray(toks), jnp.asarray(mask),
                                  cosine_lr(lr, step, steps))
        if step % log_every == 0 or step == steps - 1:
            print(f"  [lora {cfg.name}/{task}] step {step:4d} loss {float(loss):.4f}", flush=True)
    return lora


def quick_eval(cfg, params, lora, task, rng, n=64):
    """Greedy-decode exact-match rate (sanity check; the real eval is rust)."""
    prompts, plens, refs, rlens = tasks.make_eval_set(task, rng, n)
    merged = M.merge_lora(cfg, params, lora) if lora is not None else params
    fwd = jax.jit(lambda t: M.forward(cfg, merged, t))
    toks = jnp.asarray(prompts)
    correct = 0
    for i in range(n):
        seq = np.array(prompts[i])
        pos = int(plens[i])
        for _ in range(int(rlens[i])):
            logits = fwd(jnp.asarray(seq[None]))[0]
            nxt = int(jnp.argmax(logits[pos - 1]))
            seq[pos] = nxt
            pos += 1
        got = seq[plens[i] : plens[i] + rlens[i]]
        if np.array_equal(got, refs[i, : rlens[i]]):
            correct += 1
    _ = toks
    return correct / n


def capture_calibration(cfg, params, lora, rng, n_rows=256, batch_size=16, task="copy"):
    """Per-site input activations for GPTQ's Hessian (subsampled rows)."""
    toks, _ = tasks.make_batch(task, rng, batch_size)
    _, taps = M.forward_with_taps(cfg, params, jnp.asarray(toks), lora)
    out = {}
    for name, act in taps.items():
        a = np.asarray(act)
        idx = rng.choice(a.shape[0], size=min(n_rows, a.shape[0]), replace=False)
        out[name] = a[idx].astype(np.float32)
    return out


# ---------------------------------------------------------------------------
# Export
# ---------------------------------------------------------------------------
def export_model(cfg, params, out_dir):
    tensors = {k: np.asarray(v, np.float32) for k, v in params.items()}
    tensorfile.save(os.path.join(out_dir, "base.bin"), tensors)
    meta = {
        "d_model": np.array([cfg.d_model], np.int32),
        "n_layers": np.array([cfg.n_layers], np.int32),
        "n_heads": np.array([cfg.n_heads], np.int32),
        "d_ff": np.array([cfg.d_ff], np.int32),
        "vocab": np.array([cfg.vocab], np.int32),
        "seq_len": np.array([cfg.seq_len], np.int32),
        "lora_rank": np.array([cfg.lora_rank], np.int32),
        "lora_alpha": np.array([cfg.lora_alpha], np.int32),
        "act_silu": np.array([1 if cfg.act == "silu" else 0], np.int32),
    }
    tensorfile.save(os.path.join(out_dir, "meta.bin"), meta)


def export_lora(lora, path):
    tensorfile.save(path, {k: np.asarray(v, np.float32) for k, v in lora.items()})


def export_eval_set(task, rng, n, path):
    prompts, plens, refs, rlens = tasks.make_eval_set(task, rng, n)
    tensorfile.save(path, {
        "prompts": prompts, "plens": plens, "refs": refs, "rlens": rlens,
        "exact": np.array([1 if tasks.EXACT_MATCH[task] else 0], np.int32),
    })


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="tiny-llama-s,tiny-llama-m,tiny-mistral-s")
    ap.add_argument("--tasks", default=",".join(tasks.TASKS))
    ap.add_argument("--pretrain-steps", type=int, default=400)
    ap.add_argument("--lora-steps", type=int, default=700)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--pretrain-lr", type=float, default=2e-3)
    ap.add_argument("--lora-lr", type=float, default=8e-3)
    ap.add_argument("--eval-n", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    t0 = time.time()
    for mname in args.models.split(","):
        cfg = M.MODELS[mname]
        out_dir = os.path.join(args.out, mname)
        os.makedirs(out_dir, exist_ok=True)
        rng = np.random.default_rng(args.seed)
        base_path = os.path.join(out_dir, "base.bin")
        if os.path.exists(base_path):
            # resume: reuse the pretrained base (jax arrays from tensorfile)
            print(f"== {mname}: reusing pretrained base", flush=True)
            params = {k: jnp.asarray(v) for k, v in tensorfile.load(base_path).items()}
        else:
            print(f"== {mname}: pretraining base ({args.pretrain_steps} steps)", flush=True)
            params = pretrain_base(cfg, rng, args.pretrain_steps, args.batch_size, args.pretrain_lr)
            export_model(cfg, params, out_dir)
        for task in args.tasks.split(","):
            if os.path.exists(os.path.join(out_dir, f"{task}.lora.bin")):
                print(f"== {mname}/{task}: already trained, skipping", flush=True)
                continue
            print(f"== {mname}/{task}: training LoRA ({args.lora_steps} steps)", flush=True)
            lora = train_lora(cfg, params, task, rng, args.lora_steps, args.batch_size, args.lora_lr)
            em = quick_eval(cfg, params, lora, task, np.random.default_rng(args.seed + 1), n=48)
            em0 = quick_eval(cfg, params, None, task, np.random.default_rng(args.seed + 1), n=24)
            print(f"   fp16 LoRA EM={em:.3f} (base alone EM={em0:.3f})", flush=True)
            export_lora(lora, os.path.join(out_dir, f"{task}.lora.bin"))
            export_eval_set(task, np.random.default_rng(args.seed + 2), args.eval_n,
                            os.path.join(out_dir, f"{task}.eval.bin"))
            calib = capture_calibration(cfg, params, lora, np.random.default_rng(args.seed + 3),
                                        task=task)
            tensorfile.save(os.path.join(out_dir, f"{task}.calib.bin"), calib)
    print(f"training done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
