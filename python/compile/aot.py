"""AOT: lower the L2 model forward and the L1 Pallas kernel to HLO text.

HLO **text** (not `.serialize()`): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Outputs (under artifacts/):
    <model>.fwd.b<B>.hlo.txt    forward (tokens[B,T], *weights) -> (logits,)
                                for batch buckets B in BUCKETS
    lora_apply.hlo.txt          fused quantized sub-LoRA apply (L1 kernel)
    manifest.txt                one line per artifact: name, inputs, shapes
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

BUCKETS = [1, 8]

# Shapes for the standalone kernel artifact (tiny-llama-s attention site,
# rho=0.9-ish split: h=4 high components, rl=12 low).
KERNEL_SHAPE = dict(bsz=8, n=128, m=128, h=4, rl=12, group=64)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fwd(cfg, bsz):
    """Lower the flat-signature forward for one batch bucket."""
    specs = [jax.ShapeDtypeStruct((bsz, cfg.seq_len), jnp.int32)]
    dummy = M.init_params(cfg, jax.random.PRNGKey(0))
    for name in M.param_names(cfg):
        specs.append(jax.ShapeDtypeStruct(dummy[name].shape, jnp.float32))
    return jax.jit(M.fwd_flat(cfg)).lower(*specs)


def lower_lora_apply():
    from .kernels import lora_apply as K

    s = KERNEL_SHAPE
    bsz, n, m, h, rl, g = s["bsz"], s["n"], s["m"], s["h"], s["rl"], s["group"]
    f32, u8 = jnp.float32, jnp.uint8
    specs = [
        jax.ShapeDtypeStruct((bsz, n), f32),
        jax.ShapeDtypeStruct((h, n // 4), u8),
        jax.ShapeDtypeStruct((h, n // g), f32),
        jax.ShapeDtypeStruct((h, n // g), f32),
        jax.ShapeDtypeStruct((h, m // 4), u8),
        jax.ShapeDtypeStruct((h, m // g), f32),
        jax.ShapeDtypeStruct((h, m // g), f32),
        jax.ShapeDtypeStruct((rl, n // 8), u8),
        jax.ShapeDtypeStruct((rl, n // g), f32),
        jax.ShapeDtypeStruct((rl, m // 8), u8),
        jax.ShapeDtypeStruct((rl, m // g), f32),
    ]

    def f(*args):
        return (K.lora_apply_pallas(*args, group=g),)

    return jax.jit(f).lower(*specs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="tiny-llama-s,tiny-llama-m,tiny-mistral-s")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = []
    for mname in args.models.split(","):
        cfg = M.MODELS[mname]
        for bsz in BUCKETS:
            path = os.path.join(args.out, f"{mname}.fwd.b{bsz}.hlo.txt")
            text = to_hlo_text(lower_fwd(cfg, bsz))
            with open(path, "w") as f:
                f.write(text)
            manifest.append(
                f"{mname}.fwd.b{bsz}: tokens i32[{bsz},{cfg.seq_len}] "
                f"+ {len(M.param_names(cfg))} weights -> logits f32[{bsz},{cfg.seq_len},{cfg.vocab}]"
            )
            print(f"wrote {path} ({len(text)} chars)", flush=True)

    path = os.path.join(args.out, "lora_apply.hlo.txt")
    text = to_hlo_text(lower_lora_apply())
    with open(path, "w") as f:
        f.write(text)
    s = KERNEL_SHAPE
    manifest.append(
        f"lora_apply: x f32[{s['bsz']},{s['n']}] h={s['h']} rl={s['rl']} "
        f"group={s['group']} -> y f32[{s['bsz']},{s['m']}]"
    )
    print(f"wrote {path} ({len(text)} chars)", flush=True)

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")


if __name__ == "__main__":
    main()
