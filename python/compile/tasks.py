"""Synthetic task suite mirroring the paper's evaluation domains.

The paper evaluates LoRA adapters on math reasoning (GSM8K/MATH), code
generation (HumanEval) and summarization (XSum).  On this substrate we train
tiny transformers, so each domain is replaced by a synthetic task that keeps
the *failure mode* of its metric (see DESIGN.md §2):

  modadd    — digit-wise modular addition (exact match)        ~ GSM8K
  modchain  — global reductions over a digit string (EM)       ~ MATH
  transform — apply a small "program" to a token list (EM)     ~ HumanEval
  keyword   — extract marked salient tokens (ROUGE-L)          ~ XSum

All tasks share one vocabulary and a fixed sequence layout:

  [BOS, prompt..., SEP, answer..., EOS, PAD...]   (length = SEQ_LEN)

The same token ids are hard-coded on the rust side (rust/src/eval/tasks.rs);
changing them is a cross-layer breaking change.
"""

import numpy as np

# ---------------------------------------------------------------------------
# Vocabulary
# ---------------------------------------------------------------------------
PAD, BOS, EOS, SEP, MARK = 0, 1, 2, 3, 4
DIGIT0 = 5          # tokens 5..14 are digits 0..9
LETTER0 = 15        # tokens 15..30 are "letters" a..p (16 symbols)
OP0 = 31            # tokens 31..38 are transform ops
VOCAB = 64
SEQ_LEN = 32

OPS = ["rev", "rot1", "rot2", "swap_halves", "first3", "neg"]


def digit(d):
    return DIGIT0 + int(d)


def letter(i):
    return LETTER0 + int(i)


TASKS = ["modadd", "modchain", "transform", "keyword"]
# Which tasks are scored with exact match (vs ROUGE-L) — mirrored in rust.
EXACT_MATCH = {"modadd": True, "modchain": True, "transform": True, "keyword": False}


# ---------------------------------------------------------------------------
# Per-task generators: return (prompt_tokens, answer_tokens)
# ---------------------------------------------------------------------------
def gen_modadd(rng):
    """GSM8K analog: two single-digit operands -> (sum mod 10, product mod 10).

    Two 100-entry fact tables, multi-token exact-match answer: learnable by a
    tiny model in a few hundred LoRA steps, yet all-or-nothing like pass@1.
    """
    a, b = int(rng.integers(0, 10)), int(rng.integers(0, 10))
    prompt = [digit(a), MARK, digit(b)]
    answer = [digit((a + b) % 10), digit((a * b) % 10)]
    return prompt, answer


def gen_modchain(rng):
    """MATH analog (harder): chained sums. prompt a,b,c -> ((a+b)%10, (a+b+c)%10).

    The second token composes two table lookups; accuracy stays well below
    modadd, mirroring MATH < GSM8K in the paper.
    """
    a, b, c = (int(rng.integers(0, 10)) for _ in range(3))
    prompt = [digit(a), digit(b), digit(c)]
    answer = [digit((a + b) % 10), digit((a + b + c) % 10)]
    return prompt, answer


def _apply_op(op, xs):
    xs = list(xs)
    if op == "rev":
        return xs[::-1]
    if op == "rot1":
        return xs[1:] + xs[:1]
    if op == "rot2":
        return xs[2:] + xs[:2]
    if op == "swap_halves":
        h = len(xs) // 2
        return xs[h:] + xs[:h]
    if op == "first3":
        return xs[:3] + [0, 0, 0]
    if op == "neg":
        return [15 - x for x in xs]
    raise ValueError(op)


def gen_transform(rng):
    """Program execution: OP + 6 letters -> transformed 6 letters (all-or-nothing)."""
    op_idx = int(rng.integers(0, len(OPS)))
    xs = rng.integers(0, 16, size=6)
    prompt = [OP0 + op_idx] + [letter(x) for x in xs]
    answer = [letter(x) for x in _apply_op(OPS[op_idx], xs)]
    return prompt, answer


def gen_keyword(rng):
    """Extractive summary: 12 letters, 3 preceded by MARK; emit marked ones."""
    xs = rng.integers(0, 16, size=12)
    marked = sorted(rng.choice(12, size=3, replace=False).tolist())
    prompt, answer = [], []
    for i, x in enumerate(xs):
        if i in marked:
            prompt.append(MARK)
            answer.append(letter(x))
        prompt.append(letter(x))
    return prompt, answer


def gen_copy(rng):
    """Base-model pretraining task: echo the prompt after SEP.

    Teaches sequence format + attention over the FULL symbol range
    (digits, letters, ops, MARK) so every embedding the downstream tasks
    touch is trained; the task mappings themselves are never seen.
    """
    n = int(rng.integers(3, 12))
    toks = rng.integers(MARK, OP0 + len(OPS), size=n).tolist()
    return toks, list(toks)


GENERATORS = {
    "modadd": gen_modadd,
    "modchain": gen_modchain,
    "transform": gen_transform,
    "keyword": gen_keyword,
    "copy": gen_copy,
}


# ---------------------------------------------------------------------------
# Sequence assembly
# ---------------------------------------------------------------------------
def assemble(prompt, answer):
    """Pack prompt/answer into fixed-length token + loss-mask arrays.

    The loss mask is 1 on the answer tokens and the EOS (the region the model
    must *produce*), 0 elsewhere.
    """
    toks = [BOS] + list(prompt) + [SEP] + list(answer) + [EOS]
    assert len(toks) <= SEQ_LEN, f"sequence too long: {len(toks)}"
    mask = [0] * (len(prompt) + 2) + [1] * (len(answer) + 1)
    toks = toks + [PAD] * (SEQ_LEN - len(toks))
    mask = mask + [0] * (SEQ_LEN - len(mask))
    return np.array(toks, np.int32), np.array(mask, np.float32)


def make_batch(task, rng, batch_size):
    """Batch of (tokens[B,T], mask[B,T]) for training."""
    ts, ms = [], []
    gen = GENERATORS[task]
    for _ in range(batch_size):
        p, a = gen(rng)
        t, m = assemble(p, a)
        ts.append(t)
        ms.append(m)
    return np.stack(ts), np.stack(ms)


def make_eval_set(task, rng, n):
    """Eval set: prompts (padded), prompt lengths, reference answers (padded).

    prompt_tokens[i] = [BOS, prompt..., SEP, PAD...]; the decoder starts
    generating right after SEP.
    """
    gen = GENERATORS[task]
    prompts = np.zeros((n, SEQ_LEN), np.int32)
    plens = np.zeros((n,), np.int32)
    refs = np.zeros((n, SEQ_LEN), np.int32)
    rlens = np.zeros((n,), np.int32)
    for i in range(n):
        p, a = gen(rng)
        seq = [BOS] + list(p) + [SEP]
        prompts[i, : len(seq)] = seq
        plens[i] = len(seq)
        refs[i, : len(a)] = a
        rlens[i] = len(a)
    return prompts, plens, refs, rlens
