//! END-TO-END DRIVER (DESIGN.md §6): the full three-layer system on a real
//! workload.
//!
//! Loads the AOT-compiled tiny-llama-s forward (HLO text → PJRT), registers
//! the trained task adapters — FP16 *and* LoRAQuant(2@0.9) — plus a fleet
//! of quantized tenant clones, replays a Poisson/Zipf workload through the
//! coordinator, and reports:
//!   * task quality (exact match / ROUGE-L) FP16 vs quantized,
//!   * serving latency percentiles + throughput,
//!   * batching / cache behaviour,
//!   * adapter memory at rest.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_multi_lora
//! ```

use loraquant::adapter::LoraAdapter;
use loraquant::coordinator::{Coordinator, CoordinatorConfig, GenRequest, StoredAdapter};
use loraquant::eval::{EvalSet, TOKENS};
use loraquant::eval::rouge_l;
use loraquant::experiments::{lq, Settings};
use loraquant::loraquant::{quantize_site, QuantizedLora};
use loraquant::workload::{generate, WorkloadConfig};
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let settings = Settings::from_env();
    let Some(model) = settings.models.first().cloned() else {
        anyhow::bail!("no artifacts — run `make artifacts` first");
    };
    let dir = settings.artifacts.join(&model);
    let tasks = ["modadd", "modchain", "transform", "keyword"];

    // 4 executor workers (adapter-affinity routed) + 2 merge threads; a
    // batch decodes on the smallest compiled bucket that fits it.
    let mut cfg = CoordinatorConfig::new(&settings.artifacts, &model)
        .with_workers(4)
        .with_buckets(vec![1, 8]);
    cfg.max_wait = Duration::from_millis(5);
    let (coord, join) = Coordinator::start(cfg)?;
    println!("== serve_multi_lora: model {model} (4-worker pool)");

    // --- register FP16 + quantized variants of each task adapter ---------
    let qcfg = lq(2, 0.9);
    let mut fp_ids = Vec::new();
    let mut q_ids = Vec::new();
    let mut fp_bytes = 0usize;
    let mut q_bytes = 0usize;
    for task in tasks {
        let lora = LoraAdapter::load(dir.join(format!("{task}.lora.bin")))?;
        let mut q = QuantizedLora::default();
        for (site, (a, b)) in &lora.sites {
            q.sites.insert(site.clone(), quantize_site(b, a, &qcfg));
        }
        fp_bytes += lora.fp16_bytes();
        q_bytes += q.packed_bytes();
        fp_ids.push(coord.register_adapter(StoredAdapter::Fp16(lora), task)?);
        q_ids.push(coord.register_adapter(StoredAdapter::Quantized(q), task)?);
    }
    println!(
        "adapters at rest: fp16 {} KB vs LoRAQuant {} KB ({:.1}x smaller)",
        fp_bytes / 1024,
        q_bytes / 1024,
        fp_bytes as f64 / q_bytes as f64
    );

    // --- task quality through the SERVING path (not the eval harness) ----
    println!("\ntask quality via served requests (64 examples/task):");
    for (t, task) in tasks.iter().enumerate() {
        let set = EvalSet::load(dir.join(format!("{task}.eval.bin")))?.truncated(64);
        let fp = served_score(&coord, fp_ids[t], &set)?;
        let qd = served_score(&coord, q_ids[t], &set)?;
        println!(
            "  {task:<10} fp16 = {fp:6.2}   LoRAQuant(2@0.9) = {qd:6.2}   ({})",
            if set.exact { "exact match" } else { "ROUGE-L" }
        );
    }

    // --- multi-tenant fleet + Zipf workload ------------------------------
    let n_tenants = 24;
    let mut fleet = q_ids.clone();
    for i in 0..n_tenants - fleet.len() {
        let task = tasks[i % tasks.len()];
        let lora = LoraAdapter::load(dir.join(format!("{task}.lora.bin")))?;
        let mut q = QuantizedLora::default();
        for (site, (a, b)) in &lora.sites {
            q.sites.insert(site.clone(), quantize_site(b, a, &qcfg));
        }
        fleet.push(coord.register_adapter(StoredAdapter::Quantized(q), task)?);
    }
    // warm the whole fleet off the request path before traffic arrives
    let t0 = Instant::now();
    let warm: Vec<_> = fleet.iter().map(|&id| coord.prefetch(id)).collect();
    for rx in warm {
        rx.recv()??;
    }
    println!("prefetched {} tenants in {:?}", fleet.len(), t0.elapsed());

    let wl = WorkloadConfig { rate: 150.0, n_requests: 192, zipf_alpha: 1.1, seed: 3 };
    let schedule = generate(&wl, &fleet);
    println!("\nreplaying {} requests over {} tenants (Poisson 150/s, Zipf 1.1)…", schedule.len(), fleet.len());
    let start = Instant::now();
    let mut rxs = Vec::new();
    for arr in &schedule {
        let el = start.elapsed();
        if arr.at > el {
            std::thread::sleep(arr.at - el);
        }
        rxs.push(coord.generate_async(GenRequest::new(
            arr.adapter,
            vec![TOKENS::BOS, 5, TOKENS::MARK, 7, TOKENS::SEP],
            3,
        )));
    }
    let ok = rxs.into_iter().filter(|rx| matches!(rx.recv(), Ok(Ok(_)))).count();
    let wall = start.elapsed();
    let (m, cache, nreg) = coord.metrics()?;
    println!("served {ok}/{} in {wall:.2?} ({:.1} req/s)", schedule.len(), ok as f64 / wall.as_secs_f64());
    println!("  {}", m.summary());
    println!(
        "  cache: hit_rate={:.2} evictions={} | registry: {} adapters",
        cache.hit_rate(),
        cache.evictions,
        nreg
    );
    for s in coord.metrics_per_worker()? {
        println!(
            "  worker {}: requests={} batches={} cached_adapters={}",
            s.worker, s.metrics.requests, s.metrics.batches, s.cached_adapters
        );
    }
    coord.shutdown();
    let _ = join.join();
    println!("\nOK — all three layers composed: HLO artifacts (L2/L1) executed by the");
    println!("rust coordinator (L3) with quantized adapters on the request path.");
    Ok(())
}

/// Score an adapter by issuing its eval set through the serving path.
fn served_score(
    coord: &Coordinator,
    adapter: u32,
    set: &EvalSet,
) -> anyhow::Result<f64> {
    let mut rxs = Vec::new();
    for i in 0..set.len() {
        let prompt = set.prompts[i][..set.plens[i]].to_vec();
        rxs.push(coord.generate_async(GenRequest::new(adapter, prompt, set.refs[i].len())));
    }
    let mut total = 0.0;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv()??;
        total += if set.exact {
            f64::from(resp.tokens == set.refs[i])
        } else {
            rouge_l(&resp.tokens, &set.refs[i])
        };
    }
    Ok(100.0 * total / set.len() as f64)
}
