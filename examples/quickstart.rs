//! Quickstart: quantize one LoRA adapter with LoRAQuant and inspect the
//! result — no artifacts needed (synthetic adapter with a realistic
//! decaying spectrum).
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use loraquant::baselines::{FlatQuantizer, Quantizer};
use loraquant::loraquant::{quantize_site, LoraQuantConfig};
use loraquant::tensor::matmul;
use loraquant::testutil::Rng;

fn main() {
    // A rank-16 adapter for a 512x128 linear site, spectrum decaying like a
    // trained LoRA's.
    let mut rng = Rng::new(42);
    let (b, a) = rng.lora_pair(512, 128, 16, 0.7);
    let ba = matmul(&b, &a);

    println!("LoRAQuant quickstart — one 512x128 rank-16 adapter\n");
    for (bits, rho) in [(2u32, 0.8f32), (2, 0.9), (3, 0.8), (3, 0.9)] {
        let cfg = LoraQuantConfig::variant(bits, rho);
        let site = quantize_site(&b, &a, &cfg);
        let err = site.dequant_delta().rel_err(&ba);
        println!(
            "LoRAQuant({bits}@{rho}):  h={:<2}  avg_bits={:.3}  packed={:>6} B  rel_err={:.3}",
            site.h,
            site.avg_bits(),
            site.packed_bytes(),
            err
        );
    }

    println!("\nbaselines at similar budgets:");
    for (q, label) in [
        (FlatQuantizer::bin(128), "BIN        "),
        (FlatQuantizer::rtn(1, 128), "RTN (1 bit)"),
        (FlatQuantizer::rtn(2, 128), "RTN (2 bit)"),
    ] {
        let c = q.quantize(&b, &a, None);
        println!(
            "{label}:  avg_bits={:.3}  rel_err={:.3}",
            c.avg_bits(),
            c.dequant_delta().rel_err(&ba)
        );
    }
    println!("\nFP16 baseline: avg_bits=16.000  rel_err=0.000");
    println!("\nThe mixed-precision split keeps the error of sub-2-bit storage");
    println!("well below flat 1-bit methods — the paper's core claim in weight space.");
}
