//! Quality/bits frontier sweep (a runnable mini Figure 4): sweep ρ and the
//! high-precision bitwidth on one trained adapter and print the
//! (avg_bits → task score) curve through the real runtime.
//!
//! ```sh
//! make artifacts && cargo run --release --example quality_sweep -- --task modadd
//! ```

use loraquant::cli::Args;
use loraquant::experiments::{ModelCtx, Settings};
use loraquant::loraquant::{quantize_site, LoraQuantConfig, QuantizedLora};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let task = args.str_or("task", "modadd");
    let settings = Settings::from_env();
    let Some(model) = settings.models.first().cloned() else {
        anyhow::bail!("no artifacts — run `make artifacts` first");
    };
    let ctx = ModelCtx::load(&settings, &model)?;
    let td = ctx
        .tasks
        .iter()
        .find(|t| t.task == task)
        .ok_or_else(|| anyhow::anyhow!("task {task} not trained"))?;

    println!("quality vs bits frontier — {model}/{task} ({} eval examples)", td.eval.len());
    println!("{:<8} {:<6} {:>9} {:>9}", "bits_hi", "rho", "avg_bits", "score");
    for bits in [2u32, 3] {
        for rho in [0.5f32, 0.7, 0.8, 0.9, 0.95] {
            let cfg = LoraQuantConfig { group: 128, ..LoraQuantConfig::variant(bits, rho) };
            let mut q = QuantizedLora::default();
            for (site, (a, b)) in &td.lora.sites {
                q.sites.insert(site.clone(), quantize_site(b, a, &cfg));
            }
            let deltas = loraquant::model::merge::quant_deltas(&q);
            let score = ctx.eval_deltas(&deltas, &td.eval)?;
            println!("{bits:<8} {rho:<6} {:>9.3} {score:>9.2}", q.avg_bits());
        }
    }
    let fp = ctx.eval_deltas(&loraquant::model::merge::fp_deltas(&td.lora), &td.eval)?;
    println!("{:<8} {:<6} {:>9.3} {fp:>9.2}", "fp16", "-", 16.0);
    Ok(())
}
