//! Memory-footprint planner (runnable App. D / Figure 6): how much memory a
//! deployment needs for N customized tenants, FP16 vs LoRAQuant, using the
//! real trained adapter sizes and the registry's byte accounting.
//!
//! ```sh
//! make artifacts && cargo run --release --example memory_footprint -- --tenants 500
//! ```

use loraquant::adapter::LoraAdapter;
use loraquant::cli::Args;
use loraquant::experiments::{lq, Settings};
use loraquant::loraquant::{quantize_site, QuantizedLora};
use loraquant::model::BaseWeights;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let tenants = args.usize_or("tenants", 200)?;
    let settings = Settings::from_env();
    let Some(model) = settings.models.first().cloned() else {
        anyhow::bail!("no artifacts — run `make artifacts` first");
    };
    let dir = settings.artifacts.join(&model);
    let base = BaseWeights::load(&dir)?;
    let lora = LoraAdapter::load(dir.join("modadd.lora.bin"))?;

    let mut q29 = QuantizedLora::default();
    let mut q38 = QuantizedLora::default();
    for (site, (a, b)) in &lora.sites {
        q29.sites.insert(site.clone(), quantize_site(b, a, &lq(2, 0.9)));
        q38.sites.insert(site.clone(), quantize_site(b, a, &lq(3, 0.8)));
    }

    println!("memory planner — {model}, {tenants} tenants, one adapter each");
    println!("base model (fp16): {:>10} bytes", base.fp16_bytes());
    println!("adapter fp16     : {:>10} bytes/tenant", lora.fp16_bytes());
    println!("LoRAQuant(2@0.9) : {:>10} bytes/tenant ({:.2} avg bits)", q29.packed_bytes(), q29.avg_bits());
    println!("LoRAQuant(3@0.8) : {:>10} bytes/tenant ({:.2} avg bits)", q38.packed_bytes(), q38.avg_bits());
    println!();
    println!("{:<22} {:>14} {:>14} {:>8}", "configuration", "total bytes", "vs base", "saving");
    let base_b = base.fp16_bytes() as f64;
    for (label, per) in [
        ("fp16 adapters", lora.fp16_bytes()),
        ("LoRAQuant(2@0.9)", q29.packed_bytes()),
        ("LoRAQuant(3@0.8)", q38.packed_bytes()),
    ] {
        let total = base_b + (per * tenants) as f64;
        println!(
            "{label:<22} {total:>14.0} {:>13.2}x {:>7.1}%",
            total / base_b,
            100.0 * (1.0 - total / (base_b + (lora.fp16_bytes() * tenants) as f64))
        );
    }
    println!();
    println!(
        "at {tenants} tenants, fp16 adapters alone cost {:.1}x the base model;",
        (lora.fp16_bytes() * tenants) as f64 / base_b
    );
    println!(
        "LoRAQuant keeps the whole fleet at {:.2}x base — the paper's App. D story.",
        (base_b + (q29.packed_bytes() * tenants) as f64) / base_b
    );
    Ok(())
}
